"""End-to-end behaviour tests for the paper's system.

Covers: the full autotune->roofline pipeline on a synthetic machine model
(no timing flakiness), training-loop loss descent on CPU, serving decode,
and the production dry-run via subprocess (512 placeholder devices)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (EvaluationSettings, Tuner, from_measurements,
                        grid, standard_techniques)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# The paper's full pipeline on a deterministic synthetic "machine"
# ---------------------------------------------------------------------------

def synthetic_machine_benchmark(rng):
    """GFLOP/s surface with a known peak at (n=1000, m=4096, k=128) — shaped
    after the paper's Table V observation (non-square optima, k=128)."""

    def bench(cfg):
        n, m, k = cfg["n"], cfg["m"], cfg["k"]
        base = 400.0
        base *= 1.0 - 0.25 * abs(np.log2(k / 128.0)) / 4.0
        base *= 1.0 - 0.1 * abs(np.log2(n / 1000.0))
        base *= 1.0 - 0.05 * abs(np.log2(m / 4096.0))
        # square matrices are deliberately NOT optimal
        if n == m == k:
            base *= 0.55

        def factory():
            def sample():
                return float(rng.normal(base, 2.0))
            return sample

        return factory

    return bench


def test_paper_pipeline_on_synthetic_machine(rng):
    space = grid(n=(500, 1000, 2000), m=(1024, 4096), k=(64, 128, 512))
    base = EvaluationSettings(max_invocations=4, max_iterations=60,
                              max_time_s=10.0)
    results = {}
    for label, (settings, order) in standard_techniques(base).items():
        results[label] = Tuner(space, settings, order=order).tune(
            synthetic_machine_benchmark(rng))
    ref = results["Default"]
    assert ref.best_config == {"n": 1000, "m": 4096, "k": 128}
    for label, tr in results.items():
        # every technique agrees on the optimum...
        assert tr.best_config == ref.best_config, label
        # ...within the paper's 2% result-error criterion
        assert abs(tr.best_score - ref.best_score) / ref.best_score < 0.02
    # and the optimized run needs far fewer samples
    assert results["C+I+Outer"].total_samples < ref.total_samples / 4

    # assemble the roofline from the tuned peak (paper's end product)
    model = from_measurements("synthetic", ref.best_score * 1e9,
                              {"dram": 50e9})
    assert model.bound(1 / 12, "dram") == "memory"
    assert model.attainable(1e4, "dram") == ref.best_score * 1e9


def test_training_loss_decreases():
    from repro.launch.train import train
    r = train("mamba2_130m", steps=40, batch=4, seq=64, smoke=True,
              log_every=1000)
    assert r["losses"][-1] < r["losses"][0] - 0.05


def test_serve_generates():
    from repro.launch.serve import serve
    r = serve("granite_3_2b", batch=2, prompt_len=16, gen=4, smoke=True)
    assert r["tokens"].shape == (2, 4)
    assert (r["tokens"] >= 0).all()


@pytest.mark.slow
def test_dryrun_subprocess_single_cell():
    """The real multi-pod dry-run entry point, in a fresh process so the
    512-device XLA flag applies."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite_3_2b", "--shape", "decode_32k", "--mesh", "multi",
         "--no-analysis"],
        env={**os.environ, "PYTHONPATH": SRC}, capture_output=True,
        text=True, timeout=900, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


def test_dryrun_records_complete():
    """If the full sweep has been run, every non-skipped cell must be ok and
    every long_500k skip must be one of the 7 documented full-attention
    archs."""
    paths = [os.path.join(REPO, "results", "dryrun.jsonl"),
             os.path.join(REPO, "results", "dryrun_b.jsonl")]
    records = []
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                records += [json.loads(line) for line in f]
    if not records:
        pytest.skip("dry-run sweep not executed yet")
    allowed_skips = {"command_r_plus_104b", "granite_3_2b", "minicpm_2b",
                     "gemma_2b", "whisper_base", "granite_moe_1b_a400m",
                     "llama_3_2_vision_11b"}
    for r in records:
        if r["status"] == "skipped":
            assert r["shape"] == "long_500k" and r["arch"] in allowed_skips
        else:
            assert r["status"] == "ok", r

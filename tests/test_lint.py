"""Measurement-soundness linter (repro.lint): finding codes on broken
fixtures, suppression syntax, the CLI JSON contract, and the Tuner's
pre-run workload audit hook."""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.lint import (CODES, LINT_VERSION, WorkloadAuditError,
                        WorkloadAuditWarning, check_lock_discipline,
                        check_lock_source, filter_suppressed, lint_file,
                        lint_source, worst_severity)

REPO = pathlib.Path(__file__).resolve().parent.parent


def codes(findings):
    return sorted(f.code for f in findings)


def run_lint(source):
    return lint_source(textwrap.dedent(source), path="fixture.py")


# ---------------------------------------------------------------------------
# Pass 2 — harness timing pitfalls (MS2xx)
# ---------------------------------------------------------------------------

def test_ms201_timed_device_call_without_sync():
    findings = run_lint("""
        import time
        import jax.numpy as jnp

        def bench(a, b):
            t0 = time.perf_counter()
            c = jnp.dot(a, b)
            return time.perf_counter() - t0
    """)
    assert "MS201" in codes(findings)


def test_ms202_wall_clock_in_timed_region():
    findings = run_lint("""
        import time
        import jax

        def bench(f, x):
            t0 = time.time()
            jax.block_until_ready(f(x))
            return time.time() - t0
    """)
    assert "MS202" in codes(findings)
    assert "MS201" not in codes(findings)


def test_ms203_jit_inside_timed_loop():
    findings = run_lint("""
        import time
        import jax

        def bench(g, xs):
            t0 = time.perf_counter()
            for x in xs:
                f = jax.jit(g)
                jax.block_until_ready(f(x))
            return time.perf_counter() - t0
    """)
    assert "MS203" in codes(findings)


def test_ms204_discarded_device_result():
    findings = run_lint("""
        import time
        import jax

        def bench(g, x):
            f = jax.jit(g)
            t0 = time.perf_counter()
            f(x)
            jax.block_until_ready(x)
            return time.perf_counter() - t0
    """)
    assert "MS204" in codes(findings)


def test_ms205_unseeded_rng():
    findings = run_lint("""
        import numpy as np
        import random

        def data(n):
            return np.random.rand(n), random.random()
    """)
    assert codes(findings).count("MS205") == 2


def test_ms205_seeded_generators_clean():
    findings = run_lint("""
        import numpy as np
        import random

        def data(n, seed):
            rng = np.random.default_rng(seed)
            r = random.Random(seed)
            return rng.normal(size=n), r.random()
    """)
    assert "MS205" not in codes(findings)


def test_ms206_partial_tuple_sync():
    findings = run_lint("""
        import time
        import jax

        def bench(g, params, batch):
            f = jax.jit(g)
            t0 = time.perf_counter()
            logits, cache = f(params, batch)
            jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            return dt, cache
    """)
    assert "MS206" in codes(findings)


def test_ms207_jit_in_factory():
    findings = run_lint("""
        import jax
        from repro.core import timed_sampler

        def make(kernel, x):
            def factory():
                f = jax.jit(kernel)
                jax.block_until_ready(f(x))
                return timed_sampler(lambda: jax.block_until_ready(f(x)),
                                     work=1.0)
            return factory
    """)
    assert "MS207" in codes(findings)


def test_ms207_named_make_invocation():
    findings = run_lint("""
        import jax

        def make_invocation():
            return jax.jit(kernel)
    """)
    assert "MS207" in codes(findings)


def test_ms207_cached_factory_clean():
    findings = run_lint("""
        import jax
        from repro.core import default_cache, steady_sampler

        def make(kernel, x):
            def factory():
                f = default_cache().compile(kernel, (x,))
                jax.block_until_ready(f(x))
                return steady_sampler(lambda: f(x), work=1.0,
                                      sync=jax.block_until_ready)
            return factory
    """)
    assert "MS207" not in codes(findings)


def test_ms207_ignores_non_factory_scopes():
    # a compile helper may call jax.jit — it is not an invocation factory
    findings = run_lint("""
        import jax

        def compile_kernel(fn):
            return jax.jit(fn)
    """)
    assert "MS207" not in codes(findings)


def test_clean_harness_has_no_findings():
    findings = run_lint("""
        import time
        import jax

        def bench(g, x):
            f = jax.jit(g)
            jax.block_until_ready(f(x))   # pre-heat
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            return time.perf_counter() - t0
    """)
    assert findings == []


def test_t0_reassignment_starts_new_region():
    # the second region syncs; only the first should be flagged
    findings = run_lint("""
        import time
        import jax.numpy as jnp
        import jax

        def bench(a, b):
            t0 = time.perf_counter()
            c = jnp.dot(a, b)
            dt1 = time.perf_counter() - t0
            t0 = time.perf_counter()
            jax.block_until_ready(jnp.dot(a, b))
            dt2 = time.perf_counter() - t0
            return dt1, dt2
    """)
    assert codes(findings) == ["MS201"]


# ---------------------------------------------------------------------------
# Suppression
# ---------------------------------------------------------------------------

def lint_fixture_file(tmp_path, source):
    fixture = tmp_path / "fixture.py"
    fixture.write_text(textwrap.dedent(source))
    return lint_file(fixture)


def test_suppression_of_named_code(tmp_path):
    findings = lint_fixture_file(tmp_path, """
        import time
        import jax

        def bench(f, x):
            t0 = time.time()   # lint: ok=MS202
            jax.block_until_ready(f(x))
            return time.perf_counter() - t0
    """)
    assert "MS202" in codes(findings)
    assert "MS202" not in codes(filter_suppressed(findings))


def test_bare_suppression_covers_all_codes(tmp_path):
    findings = lint_fixture_file(tmp_path, """
        import numpy as np

        def data(n):
            return np.random.rand(n)   # lint: ok
    """)
    assert "MS205" in codes(findings)
    assert filter_suppressed(findings) == []


def test_suppression_of_other_code_keeps_finding(tmp_path):
    findings = lint_fixture_file(tmp_path, """
        import numpy as np

        def data(n):
            return np.random.rand(n)   # lint: ok=MS999
    """)
    assert "MS205" in codes(filter_suppressed(findings))


# ---------------------------------------------------------------------------
# Pass 3 — lock discipline (MS3xx)
# ---------------------------------------------------------------------------

def test_ms301_unlocked_append():
    findings = check_lock_source(textwrap.dedent("""
        class Store:
            def put(self, line):
                with open(self.path, "a") as f:
                    f.write(line)
    """), path="store.py")
    assert "MS301" in codes(findings)


def test_ms303_truncating_rewrite():
    findings = check_lock_source(textwrap.dedent("""
        import fcntl

        class Store:
            def _flocked(self):
                f = open(self.path, "a")
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                return f

            def rewrite(self, lines):
                with self._flocked():
                    with open(self.path, "w") as f:
                        f.writelines(lines)
    """), path="store.py")
    assert "MS303" in codes(findings)


def test_locked_append_is_clean():
    findings = check_lock_source(textwrap.dedent("""
        import fcntl

        class Store:
            def _flocked(self):
                f = open(self.path, "a")
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                return f

            def put(self, line):
                with self._flocked() as f:
                    f.write(line)
    """), path="store.py")
    assert findings == []


def test_lock_targets_exist_and_are_clean():
    # regression: TrialCache.put now flocks its append (MS301) and ledger
    # rewrites go through temp+fsync+replace (MS303)
    findings = check_lock_discipline(root=REPO)
    assert findings == [], [f.render() for f in findings]


def test_serve_prefill_sync_regression():
    # regression: serve() must sync BOTH prefill outputs (MS206) and the
    # decode loop tail (MS201)
    findings = lint_file(REPO / "src" / "repro" / "launch" / "serve.py")
    assert filter_suppressed(findings) == []


# ---------------------------------------------------------------------------
# Finding plumbing
# ---------------------------------------------------------------------------

def test_all_emitted_codes_are_registered():
    assert set(CODES) >= {"MS100", "MS101", "MS102", "MS103", "MS104",
                          "MS201", "MS202", "MS203", "MS204", "MS205",
                          "MS206", "MS207", "MS301", "MS302", "MS303"}


def test_worst_severity_ordering():
    assert worst_severity([]) is None
    findings = run_lint("""
        import numpy as np

        def data(n):
            return np.random.rand(n)
    """)
    assert worst_severity(findings) == "warning"


# ---------------------------------------------------------------------------
# CLI contract (scripts/lint.py)
# ---------------------------------------------------------------------------

BROKEN_FIXTURE = textwrap.dedent("""
    import time
    import numpy as np
    import jax.numpy as jnp

    def bench(a, b):
        x = np.random.rand(4)
        t0 = time.time()
        c = jnp.dot(a, b)
        return time.time() - t0
""")


def run_cli(*argv):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "lint.py"), *argv],
        capture_output=True, text=True, timeout=120)


def test_cli_json_reports_exact_codes(tmp_path):
    fixture = tmp_path / "broken.py"
    fixture.write_text(BROKEN_FIXTURE)
    proc = run_cli("--no-trace", "--json", str(fixture))
    assert proc.returncode == 1, proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["lint_version"] == LINT_VERSION
    got = sorted(f["code"] for f in doc["findings"])
    # both time.time() calls (opening and closing the region) fire MS202
    assert got == ["MS201", "MS202", "MS202", "MS205"]
    assert doc["summary"]["error"] == 0
    assert doc["summary"]["warning"] == 4
    for f in doc["findings"]:
        assert set(f) >= {"code", "path", "line", "message", "severity",
                          "pass"}


def test_cli_clean_fixture_exits_zero(tmp_path):
    fixture = tmp_path / "clean.py"
    fixture.write_text("x = 1\n")
    proc = run_cli("--no-trace", "--json", str(fixture))
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["findings"] == []


def test_cli_missing_path_is_usage_error(tmp_path):
    proc = run_cli("--no-trace", str(tmp_path / "nope"))
    assert proc.returncode == 2


@pytest.mark.slow
def test_cli_repo_tree_is_clean():
    # the blocking CI gate: the repo's own sources must lint clean
    proc = run_cli("--no-trace")
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# Pass 1 — workload audit (traces jax kernels)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jnp():
    return pytest.importorskip("jax.numpy")


@pytest.mark.trace
def test_ms101_wrong_declared_work(jnp):
    import jax

    from repro.lint import WorkloadSpec, audit_workload
    spec = WorkloadSpec(
        fn=jnp.dot,
        args=(jax.ShapeDtypeStruct((8, 8), jnp.float32),
              jax.ShapeDtypeStruct((8, 8), jnp.float32)),
        work=8.0 * 8 * 8,     # forgot the factor of 2
        unit="flops", dtype="float32", name="bad-dgemm")
    assert "MS101" in codes(audit_workload(spec))


@pytest.mark.trace
def test_ms102_dead_kernel(jnp):
    import jax

    from repro.lint import WorkloadSpec, audit_workload

    def dead(x):
        return jnp.float32(0.0)

    spec = WorkloadSpec(
        fn=dead, args=(jax.ShapeDtypeStruct((128,), jnp.float32),),
        work=128.0, unit="flops", dtype="float32", name="dead")
    assert "MS102" in codes(audit_workload(spec))


@pytest.mark.trace
def test_ms103_dtype_mismatch(jnp):
    import jax

    from repro.lint import WorkloadSpec, audit_workload
    spec = WorkloadSpec(
        fn=jnp.dot,
        args=(jax.ShapeDtypeStruct((8, 8), jnp.float32),
              jax.ShapeDtypeStruct((8, 8), jnp.float32)),
        work=2.0 * 8 * 8 * 8,
        unit="flops", dtype="float64", name="not-actually-f64")
    assert "MS103" in codes(audit_workload(spec))


@pytest.mark.trace
def test_correct_declaration_is_clean(jnp):
    import jax

    from repro.lint import WorkloadSpec, audit_workload
    spec = WorkloadSpec(
        fn=jnp.dot,
        args=(jax.ShapeDtypeStruct((16, 4), jnp.float32),
              jax.ShapeDtypeStruct((4, 8), jnp.float32)),
        work=2.0 * 16 * 8 * 4,
        unit="flops", dtype="float32", name="good-dgemm")
    assert audit_workload(spec) == []


@pytest.mark.trace
def test_registered_benchmarks_audit_clean():
    # the benchmarks the CLI gates on must stay truthfully declared
    from benchmarks.common import AUDITED_WORKLOADS

    from repro.lint import audit_benchmark
    findings = []
    for name, (bench, cfg) in AUDITED_WORKLOADS.items():
        findings += [f for f in audit_benchmark(bench, cfg, name=name)
                     if f.severity != "info"]
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# Tuner pre-run audit hook
# ---------------------------------------------------------------------------

def _mis_declared_benchmark(jnp, calls):
    import jax

    from repro.lint import WorkloadSpec

    def bench(cfg):
        def factory():
            calls.append(cfg)
            return lambda: 1.0
        return factory

    def spec(cfg):
        n = cfg["x"] + 8
        return WorkloadSpec(
            fn=jnp.dot,
            args=(jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((n, n), jnp.float32)),
            work=float(n),          # wildly under-declared
            unit="flops", dtype="float32", name=f"mis[{n}]")

    bench.audit_spec = spec
    return bench


@pytest.fixture
def tuning_bits():
    from repro.core import EvaluationSettings
    from repro.core.searchspace import grid
    from repro.core.tuner import Tuner
    settings = EvaluationSettings(max_invocations=1, max_iterations=1,
                                  max_time_s=5.0)
    return Tuner(grid(x=(0, 1)), settings)


@pytest.mark.trace
def test_tuner_strict_raises_before_any_trial(jnp, tuning_bits):
    calls = []
    bench = _mis_declared_benchmark(jnp, calls)
    with pytest.raises(WorkloadAuditError) as exc:
        tuning_bits.tune(bench, validate="strict")
    assert calls == []             # no measurement time was burned
    assert "MS101" in codes(exc.value.findings)


@pytest.mark.trace
def test_tuner_warn_default_warns_and_proceeds(jnp, tuning_bits):
    calls = []
    bench = _mis_declared_benchmark(jnp, calls)
    with pytest.warns(WorkloadAuditWarning, match="MS101"):
        result = tuning_bits.tune(bench)    # validate="warn" is default
    assert calls                            # the run still happened
    assert result.best_config is not None


@pytest.mark.trace
def test_tuner_validate_off_is_silent(jnp, tuning_bits, recwarn):
    calls = []
    bench = _mis_declared_benchmark(jnp, calls)
    tuning_bits.tune(bench, validate="off")
    assert calls
    assert [w for w in recwarn.list
            if issubclass(w.category, WorkloadAuditWarning)] == []


def test_tuner_rejects_unknown_validate_mode(tuning_bits):
    with pytest.raises(ValueError, match="validate"):
        tuning_bits.tune(lambda cfg: lambda: (lambda: 1.0),
                         validate="sometimes")


def test_tuner_warn_mode_survives_broken_audit_spec(tuning_bits):
    # audit machinery failures must not abort a warn-mode run
    def bench(cfg):
        def factory():
            return lambda: 1.0
        return factory

    bench.audit_spec = "not callable"
    with pytest.warns(WorkloadAuditWarning, match="MS104"):
        result = tuning_bits.tune(bench)
    assert result.best_config is not None

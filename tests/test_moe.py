"""MoE dispatch invariants (GShard-style capacity routing)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models import params as P
from repro.models.config import ModelConfig


def moe_cfg(E=4, k=2, cap=1.25, group=16):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=16, vocab_size=64,
                       n_experts=E, top_k=k, capacity_factor=cap,
                       moe_group_size=group, dtype="float32")


def test_capacity_formula():
    cfg = moe_cfg(E=8, k=2, cap=1.0)
    # 64 tokens * 2 / 8 = 16 slots
    assert moe.capacity(cfg, 64) == 16
    # rounded up to a multiple of 8, floor of 8
    assert moe.capacity(cfg, 4) == 8


def test_moe_forward_shapes_finite():
    cfg = moe_cfg()
    p = P.materialize(jax.random.key(0), moe.moe_defs(cfg))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y = moe.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))


def test_dropped_tokens_fall_through_residual():
    """With capacity factor ~0 every token is dropped -> output must be
    exactly zero (the residual connection then carries the token)."""
    cfg = moe_cfg(cap=1e-9)
    assert moe.capacity(cfg, 16) == 8  # floor clamps to 8
    # to really drop, use many tokens per expert with tiny capacity:
    cfg2 = moe_cfg(E=2, k=1, cap=1e-9, group=1024)
    p = P.materialize(jax.random.key(0), moe.moe_defs(cfg2))
    x = jax.random.normal(jax.random.key(1), (1, 1024, cfg2.d_model))
    y = moe.apply_moe(p, x, cfg2)
    # capacity 8 slots per expert of >=512 candidates: almost all dropped
    zero_rows = np.mean(np.all(np.asarray(y) == 0.0, axis=-1))
    assert zero_rows > 0.9


def test_top1_equivalence_to_dense_expert():
    """With E=1, k=1 and ample capacity, MoE == that expert's FFN weighted
    by the (softmax-normalized = 1.0) gate."""
    cfg = moe_cfg(E=1, k=1, cap=2.0)
    p = P.materialize(jax.random.key(0), moe.moe_defs(cfg))
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    y = moe.apply_moe(p, x, cfg)
    w_g, w_u, w_d = (p["w_gate"][0], p["w_up"][0], p["w_down"][0])
    ref = (jax.nn.silu(x @ w_g) * (x @ w_u)) @ w_d
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_aux_loss_positive_and_balanced_bound():
    cfg = moe_cfg(E=4, k=1)
    p = P.materialize(jax.random.key(0), moe.moe_defs(cfg))
    x = jax.random.normal(jax.random.key(2), (2, 64, cfg.d_model))
    aux = float(moe.aux_load_balance_loss(p, x, cfg))
    # perfectly balanced -> 1.0; always >= 1.0 by Cauchy-Schwarz
    assert aux >= 0.99


def test_gate_weights_sum_to_one():
    """Kept tokens' combine weights are softmax over top-k: each token's
    total combine mass is <= 1 and == 1 when nothing is dropped."""
    cfg = moe_cfg(E=4, k=2, cap=4.0)
    p = P.materialize(jax.random.key(0), moe.moe_defs(cfg))
    x = jax.random.normal(jax.random.key(3), (1, 16, cfg.d_model))
    # reproduce the routing math
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    vals, _ = jax.lax.top_k(logits, cfg.top_k)
    probs = jax.nn.softmax(vals, axis=-1)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-6)

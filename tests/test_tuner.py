"""End-to-end autotuner behaviour: the paper's technique grid on synthetic
objectives with known optima."""


from repro.core.evaluator import EvaluationSettings
from repro.core.searchspace import grid
from repro.core.tuner import Tuner, compare_techniques


def make_benchmark(rng, sigma=0.5):
    """Objective: quadratic with optimum at x=7 (score 100)."""

    def bench(cfg):
        mu = 100.0 - (cfg["x"] - 7) ** 2

        def factory():
            def sample():
                return float(rng.normal(mu, sigma))
            return sample

        return factory

    return bench


BASE = EvaluationSettings(max_invocations=5, max_iterations=100,
                          max_time_s=30.0)


def test_all_techniques_find_optimum(rng):
    space = grid(x=tuple(range(12)))
    results = compare_techniques(space, make_benchmark(rng), BASE)
    assert set(results) == {"Default", "Single", "Confidence", "C+Inner",
                            "C+Inner+R", "C+I+Outer", "C+I+O+R"}
    for label, tr in results.items():
        assert tr.best_config == {"x": 7}, label


def test_optimized_uses_fewer_samples(rng):
    space = grid(x=tuple(range(12)))
    results = compare_techniques(space, make_benchmark(rng), BASE)
    default = results["Default"].total_samples
    cio = results["C+I+Outer"].total_samples
    assert default == 12 * 5 * 100           # fixed budget
    assert cio < default / 5                  # order-of-magnitude reduction


def test_result_error_below_paper_threshold(rng):
    """Paper: optimized stop conditions reproduce the Default result with
    <2% error."""
    space = grid(x=tuple(range(12)))
    results = compare_techniques(space, make_benchmark(rng), BASE)
    ref = results["Default"].best_score
    for label in ("Confidence", "C+Inner", "C+I+Outer"):
        err = abs(results[label].best_score - ref) / ref
        assert err < 0.02, (label, err)


def test_pruning_count_increases_with_incumbent_quality(rng):
    """Exhaustive order meets the optimum early (x=7 of 0..11), so most
    later configs are pruned; reverse meets it late."""
    space = grid(x=tuple(range(12)))
    results = compare_techniques(space, make_benchmark(rng), BASE)
    assert results["C+I+Outer"].n_pruned >= 1
    # reversal: the first configs (x=11, 10, 9, 8) are evaluated in full
    # until x=7 is seen; pruning still happens after
    assert results["C+I+O+R"].n_pruned >= 1


def test_progress_callback(rng):
    space = grid(x=(1, 2))
    seen = []
    tuner = Tuner(space, BASE)
    tuner.tune(make_benchmark(rng),
               progress=lambda cfg, res: seen.append(cfg["x"]))
    assert seen == [1, 2]


def test_pruned_config_never_becomes_best(rng):
    """A pruned evaluation must not override the incumbent (its score is a
    truncated estimate)."""
    space = grid(x=(7, 0))                    # optimum first, doomed second
    s = EvaluationSettings(max_invocations=3, max_iterations=50,
                           use_ci_convergence=True, use_inner_prune=True)
    tr = Tuner(space, s).tune(make_benchmark(rng, sigma=0.1))
    assert tr.best_config == {"x": 7}
    assert tr.trials[1].result.pruned


def test_successive_halving_finds_optimum(rng):
    from repro.core.tuner import tune_successive_halving
    space = grid(x=tuple(range(16)))
    base = EvaluationSettings(max_time_s=30.0)
    result = tune_successive_halving(space, make_benchmark(rng, sigma=0.2),
                                     base, eta=4)
    assert result.best_config == {"x": 7}
    # halving touches every config cheaply, then narrows
    full = 16 * 5 * 100
    assert result.total_samples < full / 10
    assert result.settings_label == "SuccessiveHalving"


def test_compare_techniques_threads_backend_cache_warm_start(tmp_path):
    """Satellite: the Tables VIII-XI grid runs parallel and resumable —
    per-technique cache namespaces, so a replay serves every trial from
    disk without cross-technique contamination."""
    from repro.core import ThreadPoolBackend, TrialCache

    def bench(cfg):
        mu = 100.0 - (cfg["x"] - 7) ** 2
        return lambda: (lambda: mu)

    space = grid(x=tuple(range(8)))
    cache = TrialCache(tmp_path / "grid.jsonl", fingerprint="fp")
    first = compare_techniques(space, bench, BASE, cache=cache,
                               backend=ThreadPoolBackend(4))
    assert all(r.best_config == {"x": 7} for r in first.values())
    assert all(r.backend == "thread" for r in first.values())
    assert all(r.n_cached == 0 for r in first.values())

    replay_cache = TrialCache(tmp_path / "grid.jsonl", fingerprint="fp")
    replay = compare_techniques(space, bench, BASE, cache=replay_cache,
                                warm_start=True)
    for label, r in replay.items():
        assert r.n_cached == len(r.trials) == 8, label
        assert r.best_config == first[label].best_config
        assert r.best_score == first[label].best_score

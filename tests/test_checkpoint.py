"""Checkpoint substrate: atomicity, roundtrip, retention, corruption."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


def tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    path = save_checkpoint(str(tmp_path), 7, tree())
    restored, manifest = load_checkpoint(path)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(restored["params"]["w"],
                                  np.arange(6.0).reshape(2, 3))
    assert restored["params"]["b"].dtype == np.dtype("bfloat16") or \
        restored["params"]["b"].dtype.name == "bfloat16"
    assert int(restored["step"]) == 7


def test_manager_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, tree())
    assert mgr.latest_step() == 30
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000020", "step_00000030"]
    restored, manifest = mgr.restore_latest()
    assert manifest["step"] == 30


def test_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "nope"))
    assert mgr.restore_latest() is None


def test_corruption_detected(tmp_path):
    path = save_checkpoint(str(tmp_path), 1, tree())
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(path)


def test_incomplete_save_is_invisible(tmp_path):
    """A .tmp directory (crash mid-save) must not be offered for restore."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree())
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert mgr.latest_step() == 5


def test_reshard_on_restore(tmp_path):
    """Elastic restore: load with explicit shardings onto the host mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    path = save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((8, 4))})
    shardings = {"w": NamedSharding(mesh, P("data", None))
                 if 8 % mesh.shape["data"] == 0
                 else NamedSharding(mesh, P(None, None))}
    restored, _ = load_checkpoint(path, shardings=shardings)
    assert restored["w"].sharding == shardings["w"]


def test_train_resume_equivalence(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + resume 3: identical loss
    trajectory (checkpoint + pure-function data pipeline)."""
    from repro.launch.train import train
    r_full = train("granite_3_2b", steps=6, batch=2, seq=32, smoke=True,
                   ckpt_dir=None, log_every=100)
    ck = str(tmp_path / "ck")
    train("granite_3_2b", steps=3, batch=2, seq=32, smoke=True,
          ckpt_dir=ck, ckpt_every=100, log_every=100)
    r_resumed = train("granite_3_2b", steps=6, batch=2, seq=32, smoke=True,
                      ckpt_dir=ck, ckpt_every=100, log_every=100)
    np.testing.assert_allclose(r_resumed["losses"][-1],
                               r_full["losses"][-1], rtol=1e-4)

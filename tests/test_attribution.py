"""Whole-model roofline attribution: per-op cost vs the empirical roofs.

Covers the attribution math on synthetic modules (join, remainder,
%-of-roof formulas), the DGEMM calibration invariant (attributed FLOPs
== declared 2·m·n·k within 1%), off-GPU graceful degradation to static
HLO-only attribution, roofs recovery from a trial cache, the dashboard
section (golden-file), the trial-row cap threading, and the report CLI.

Regenerate the golden after an intentional rendering change with:

    PYTHONPATH=src python -m pytest tests/test_attribution.py -q \
        --update-golden
"""

import json
import math
import pathlib
import subprocess
import sys

import pytest

from repro.analysis.hlo import ModuleOps, OpCost
from repro.history.render import _trials_section, render_html
from repro.obs.attribution import (AttributionReport, Roofs, attribute,
                                   _attr_op, _attribution_from_device,
                                   attribution_from_static,
                                   roofs_from_trials)
from repro.obs.device_timing import DeviceOps, normalize_op_name

REPO = pathlib.Path(__file__).resolve().parent.parent

#: handmade roofs with easy arithmetic: ridge point at I* = 100/10 = 10
ROOFS = Roofs(peak_flops=100.0, bandwidths={"hbm": 10.0, "l2": 40.0},
              fingerprint="test-roofs")


# ---------------------------------------------------------------------------
# Roofs
# ---------------------------------------------------------------------------


def test_roofs_default_subsystem_is_slowest():
    assert ROOFS.default_subsystem == "hbm"


def test_roofs_ridge_and_attainable():
    assert ROOFS.ridge() == pytest.approx(10.0)          # F_p / B_hbm
    assert ROOFS.ridge("l2") == pytest.approx(2.5)
    # below the ridge the bandwidth slope rules, above it the flat roof
    assert ROOFS.attainable(2.0) == pytest.approx(20.0)
    assert ROOFS.attainable(50.0) == pytest.approx(100.0)
    assert ROOFS.attainable(2.0, "l2") == pytest.approx(80.0)


def test_roofs_classify_by_ridge():
    assert ROOFS.classify(20.0) == ("hbm", "compute")
    assert ROOFS.classify(1.0) == ("hbm", "memory")
    assert ROOFS.classify(10.0) == ("hbm", "compute")    # at the ridge


def test_roofs_model_time_is_max_of_terms():
    # 50 FLOPs / 100 FLOP/s = 0.5s vs 100 B / 10 B/s = 10s -> memory wins
    assert ROOFS.model_time(50.0, 100.0) == pytest.approx(10.0)
    # 80 FLOPs -> 0.8s vs 1 B -> 0.1s -> compute wins
    assert ROOFS.model_time(80.0, 1.0) == pytest.approx(0.8)


def test_roofs_json_round_trip():
    d = ROOFS.to_json()
    assert d == {"peak_flops": 100.0,
                 "bandwidths": {"hbm": 10.0, "l2": 40.0},
                 "fingerprint": "test-roofs"}
    assert json.loads(json.dumps(d)) == d


# ---------------------------------------------------------------------------
# Event-name normalization (trace join key)
# ---------------------------------------------------------------------------


def test_normalize_op_name_strips_scope_and_percent():
    assert normalize_op_name("jit_f/while/body/%fusion.1") == "fusion.1"
    assert normalize_op_name("%dot.4") == "dot.4"
    assert normalize_op_name("dot.4") == "dot.4"
    assert normalize_op_name(" %copy ") == "copy"


# ---------------------------------------------------------------------------
# Per-op attribution math
# ---------------------------------------------------------------------------


def _op(name, kind, flops, bytes_accessed, modeled=True):
    return OpCost(name=name, kind=kind, flops=flops,
                  bytes_accessed=bytes_accessed, modeled=modeled)


def test_attr_op_static_saturates_roof():
    a = _attr_op(_op("dot.1", "dot", 200.0, 10.0), 2.0, ROOFS, static=True)
    assert a.pct_of_roof == 100.0
    assert a.bound == "compute"            # I = 20 >= ridge 10
    assert a.subsystem == "hbm"


def test_attr_op_measured_pct_against_attainable():
    # I = 200/10 = 20 (compute-bound): roof = F_p = 100 FLOP/s;
    # achieved 200 FLOPs / 4 s = 50 FLOP/s -> 50% of roof
    a = _attr_op(_op("dot.1", "dot", 200.0, 10.0), 4.0, ROOFS, static=False)
    assert a.pct_of_roof == pytest.approx(50.0)
    # memory-bound op: I = 5/100 = 0.05, roof = 10 * 0.05 = 0.5 FLOP/s;
    # achieved 5/20 = 0.25 FLOP/s -> 50%
    b = _attr_op(_op("f.2", "fusion", 5.0, 100.0), 20.0, ROOFS, static=False)
    assert b.bound == "memory"
    assert b.pct_of_roof == pytest.approx(50.0)


def test_attr_op_flop_free_uses_bandwidth():
    # copy moves 50 B in 10 s = 5 B/s against B_hbm = 10 -> 50%
    a = _attr_op(_op("copy.1", "copy", 0.0, 50.0), 10.0, ROOFS, static=False)
    assert a.pct_of_roof == pytest.approx(50.0)
    assert a.bound == "memory"


def test_attr_op_without_time_or_roofs():
    no_time = _attr_op(_op("d", "dot", 8.0, 4.0), None, ROOFS, static=False)
    assert no_time.pct_of_roof is None
    assert no_time.subsystem == "hbm"      # still classified
    no_roofs = _attr_op(_op("d", "dot", 8.0, 4.0), 1.0, None, static=False)
    assert no_roofs.subsystem == "unclassified"
    assert no_roofs.bound == "unclassified"
    assert no_roofs.pct_of_roof is None


def test_attr_op_json_maps_inf_intensity_to_none():
    a = _attr_op(_op("x", "exponential", 4.0, 0.0), 1.0, ROOFS, static=False)
    assert math.isinf(a.intensity)
    assert a.to_json()["intensity"] is None
    assert json.dumps(a.to_json())         # must be valid JSON


# ---------------------------------------------------------------------------
# Measured-mode assembly: join + remainder
# ---------------------------------------------------------------------------


def _module():
    return ModuleOps(ops=(
        _op("dot.1", "dot", 200.0, 10.0),          # compute-bound
        _op("fusion.2", "fusion", 5.0, 100.0),     # memory-bound
        _op("copy.3", "copy", 0.0, 50.0),          # flop-free
    ), unhandled={"rng-bit-generator": 1})


def test_device_join_and_remainder():
    device = DeviceOps(total_s=10.0,
                       by_name={"dot.1": 4.0, "fusion.2": 2.0,
                                "unmatched-kernel": 1.0},
                       n_events=4, source="test")
    rep = _attribution_from_device("w", _module(), device, ROOFS)
    assert rep.mode == "measured"
    assert rep.device_total_s == 10.0
    assert rep.attributed_s == pytest.approx(6.0)    # only joined ops
    assert rep.unattributed_s == pytest.approx(4.0)  # incl. the unmatched
    assert rep.unattributed_frac == pytest.approx(0.4)
    by = {op.name: op for op in rep.ops}
    assert by["copy.3"].time_s is None               # no device event
    assert by["dot.1"].pct_of_roof == pytest.approx(50.0)
    assert rep.unhandled == {"rng-bit-generator": 1}
    # compute-bound time under "compute", memory-bound under its subsystem
    assert rep.subsystem_seconds == {"compute": pytest.approx(4.0),
                                     "hbm": pytest.approx(2.0)}


def test_device_remainder_clamped_at_zero():
    # more joined time than track total (overlapping streams) never goes
    # negative
    device = DeviceOps(total_s=1.0, by_name={"dot.1": 2.0}, n_events=1,
                       source="test")
    rep = _attribution_from_device("w", _module(), device, ROOFS)
    assert rep.unattributed_s == 0.0


def test_top_ops_orders_by_time_then_cost():
    device = DeviceOps(total_s=10.0,
                       by_name={"dot.1": 1.0, "fusion.2": 3.0},
                       n_events=2, source="test")
    rep = _attribution_from_device("w", _module(), device, ROOFS)
    assert [o.name for o in rep.top_ops(2)] == ["fusion.2", "dot.1"]


# ---------------------------------------------------------------------------
# Static fallback (off-GPU degradation)
# ---------------------------------------------------------------------------


def test_static_report_zero_remainder_and_full_labels():
    rep = attribution_from_static("w", _module(), ROOFS, fingerprint="fp")
    assert rep.mode == "static"
    assert rep.device_total_s is None
    assert rep.unattributed_s == 0.0
    assert rep.unattributed_frac == 0.0
    for op in rep.ops:
        assert op.subsystem == "hbm"
        assert op.bound in ("compute", "memory")
        assert op.pct_of_roof == 100.0
        # static time is the roofline lower bound
        assert op.time_s == pytest.approx(
            ROOFS.model_time(op.flops, op.bytes_accessed))
    assert rep.attributed_s == pytest.approx(
        sum(op.time_s for op in rep.ops))


def test_static_without_roofs_degrades_not_raises():
    rep = attribution_from_static("w", _module(), None)
    assert all(op.subsystem == "unclassified" for op in rep.ops)
    assert all(op.time_s is None for op in rep.ops)
    assert rep.to_markdown()               # renders without roofs too
    assert json.dumps(rep.to_json())


@pytest.mark.skipif(
    __import__("jax").default_backend() != "cpu",
    reason="degradation contract only guaranteed off-accelerator")
def test_attribute_off_gpu_degrades_to_static():
    """On a CPU backend the profiler emits no device tracks, so the
    measured path must silently fall back to static attribution."""
    from repro.models.workloads import build_workload

    w = build_workload("dgemm", m=32, n=32, k=32)
    rep = attribute(w, ROOFS)              # measured path attempted
    assert rep.mode == "static"
    assert rep.device_total_s is None
    assert rep.unattributed_s == 0.0
    assert rep.ops                         # every op still labeled
    assert all(op.subsystem != "" for op in rep.ops)


# ---------------------------------------------------------------------------
# DGEMM calibration: attributed FLOPs == declared 2mnk within 1%
# ---------------------------------------------------------------------------


def test_dgemm_attributed_flops_match_declared():
    from repro.models.workloads import build_workload

    w = build_workload("dgemm", m=64, n=48, k=32)
    assert w.declared_flops == 2.0 * 64 * 48 * 32
    rep = attribute(w, ROOFS, force_static=True)
    assert rep.total_flops == pytest.approx(w.declared_flops, rel=0.01)
    # the dot op itself carries the FLOPs (not scattered over reshapes)
    dot_flops = sum(op.flops for op in rep.ops if op.kind == "dot")
    assert dot_flops == pytest.approx(w.declared_flops, rel=0.01)


def test_train_step_every_op_labeled():
    """Acceptance shape: every HLO op of a whole-model workload carries a
    subsystem label, a %-of-roof figure, and the remainder is explicit
    (exactly 0 in static mode)."""
    from repro.models.workloads import build_workload

    w = build_workload("train_step")
    rep = attribute(w, ROOFS, force_static=True)
    assert rep.ops
    for op in rep.ops:
        assert op.subsystem in ("hbm", "l2")
        assert op.pct_of_roof is not None
    assert rep.unattributed_s == 0.0
    assert rep.total_flops > 0


# ---------------------------------------------------------------------------
# Roofs from the trial cache
# ---------------------------------------------------------------------------


def _seed_cache(path):
    from test_report import synthetic_trials, write_cache

    write_cache(path, synthetic_trials())


def test_roofs_from_trials_recovers_peaks(tmp_path):
    path = tmp_path / "c.jsonl"
    _seed_cache(path)
    roofs = roofs_from_trials([str(path)], fingerprint="fpB")
    assert roofs is not None
    assert roofs.fingerprint == "fpB"
    # scores are GFLOP/s / GB/s in the cache; machine peaks are SI
    assert roofs.peak_flops == pytest.approx(900.0e9)
    assert roofs.bandwidths
    assert all(v > 0 for v in roofs.bandwidths.values())
    assert roofs.ridge() > 0


def test_roofs_from_trials_falls_back_to_first_report(tmp_path):
    path = tmp_path / "c.jsonl"
    _seed_cache(path)
    # this host's fingerprint matches neither fpA nor fpB
    roofs = roofs_from_trials([str(path)])
    assert roofs is not None
    assert roofs.fingerprint in ("fpA", "fpB")


def test_roofs_from_trials_none_when_empty(tmp_path):
    assert roofs_from_trials([str(tmp_path / "missing.jsonl")]) is None
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert roofs_from_trials([str(empty)]) is None


# ---------------------------------------------------------------------------
# Dashboard rendering
# ---------------------------------------------------------------------------


def _deterministic_report():
    """Hand-built static report — no compiler in the loop, so the golden
    is stable across jax/XLA versions."""
    module = ModuleOps(ops=(
        _op("dot.1", "dot", 2.0e6, 4.0e4),
        _op("fusion.2", "fusion", 1.0e3, 2.0e5),
        _op("copy.3", "copy", 0.0, 8.0e4),
        _op("custom-call.4", "custom-call", 0.0, 0.0, modeled=False),
    ), unhandled={"custom-call": 1})
    roofs = Roofs(peak_flops=1.0e9, bandwidths={"hbm": 1.0e8, "l2": 4.0e8},
                  fingerprint="golden-fp")
    return attribution_from_static("train_step", module, roofs,
                                   fingerprint="golden-fp")


def test_attribution_html_matches_golden(golden):
    html = render_html(
        title="Attribution test dashboard",
        subtitle="fixed subtitle for golden stability",
        attribution=_deterministic_report())
    assert "Attribution — <code>train_step</code>" in html
    assert "attr-bar" in html              # stacked subsystem bar present
    assert "static HLO attribution" in html
    golden("attribution.html", html)


def test_attribution_markdown_sections():
    md = _deterministic_report().to_markdown(max_ops=2)
    assert "## Roofline attribution: `train_step` (static)" in md
    assert "### Subsystem shares" in md
    assert "2 further ops elided" in md
    assert "*unattributed* | 0µs" in md


def test_measured_report_renders_device_basis():
    device = DeviceOps(total_s=10.0, by_name={"dot.1": 4.0}, n_events=1,
                       source="test")
    rep = _attribution_from_device("w", _module(), device, ROOFS)
    html = render_html(attribution=rep)
    assert "device total" in html
    assert "unattributed 60.0%" in html


# ---------------------------------------------------------------------------
# Trial drill-down row cap
# ---------------------------------------------------------------------------


def _trial_rows(n):
    return [{"index": i, "config": {"x": i}, "score": float(i),
             "samples": 4, "invocations": 2, "stop_reason": "max",
             "dur_s": 0.01, "worker": 0, "phases": {}} for i in range(n)]


def test_trials_section_row_cap():
    html = _trials_section(_trial_rows(5), max_rows=2)
    assert "first 2 of 5" in html
    assert _trials_section(_trial_rows(2), max_rows=2).count("<tr>") >= 2
    assert "first" not in _trials_section(_trial_rows(2), max_rows=2)


def test_render_html_threads_max_trial_rows():
    html = render_html(trials=_trial_rows(7), max_trial_rows=3)
    assert "first 3 of 7" in html
    default = render_html(trials=_trial_rows(7))
    assert "first" not in default          # default cap is 200


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_attribute_static(tmp_path):
    out_json = tmp_path / "attr.json"
    out_html = tmp_path / "dash.html"
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "roofline_report.py"),
         "--attribute", "dgemm", "--static",
         "--attribution-json", str(out_json), "--html", str(out_html),
         "--max-trial-rows", "5"],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": str(REPO / "src")})
    assert proc.returncode == 0, proc.stderr
    assert "attributed" in proc.stderr
    doc = json.loads(out_json.read_text())
    assert doc["mode"] == "static"
    assert doc["unattributed_s"] == 0.0
    assert doc["ops"]
    assert all(op["subsystem"] for op in doc["ops"])
    assert all(op["pct_of_roof"] is not None for op in doc["ops"])
    assert "Attribution —" in out_html.read_text(encoding="utf-8")

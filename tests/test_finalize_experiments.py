"""scripts/finalize_experiments.py: marker validation, --check/--in-place."""

import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

DOC_WITH_MARKERS = """# Experiments

## Dry-run

<!-- DRYRUN_TABLE -->

## Roofline

<!-- ROOFLINE_TABLE -->
"""

RECORD = {"arch": "gemma-2b", "shape": "train_4k", "mesh": "single",
          "status": "ok", "compile_s": 1.5, "peak_gb": 2.0, "args_gb": 1.0,
          "compute_ms": 10.0, "memory_ms": 5.0, "collective_ms": 1.0,
          "dominant": "compute", "useful_flops_ratio": 0.5,
          "mfu_bound": 0.4, "collectives": "all-reduce"}


def _run(cwd, *argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "finalize_experiments.py"),
         *map(str, argv)],
        capture_output=True, text=True, env=env, cwd=cwd, timeout=120)


def _write_inputs(tmp_path, doc_text=DOC_WITH_MARKERS):
    (tmp_path / "EXPERIMENTS.md").write_text(doc_text)
    rec = tmp_path / "r.jsonl"
    rec.write_text(json.dumps(RECORD) + "\n")
    return rec


def test_default_prints_finalized_doc_without_writing(tmp_path):
    rec = _write_inputs(tmp_path)
    proc = _run(tmp_path, rec)
    assert proc.returncode == 0, proc.stderr
    assert "cells: 1 ok" in proc.stdout
    assert "gemma-2b" in proc.stdout
    # stdout mode must leave the document untouched
    assert "<!-- DRYRUN_TABLE -->" in (tmp_path / "EXPERIMENTS.md").read_text()


def test_in_place_rewrites_document(tmp_path):
    rec = _write_inputs(tmp_path)
    proc = _run(tmp_path, rec, "--in-place")
    assert proc.returncode == 0, proc.stderr
    text = (tmp_path / "EXPERIMENTS.md").read_text()
    assert "<!-- DRYRUN_TABLE -->" not in text       # marker replaced
    assert "gemma-2b" in text
    assert "#### Multi-pod (512 chips)" in text


def test_missing_markers_fail_loudly(tmp_path):
    rec = _write_inputs(tmp_path, doc_text="# Experiments\n\nno markers\n")
    proc = _run(tmp_path, rec, "--in-place")
    assert proc.returncode == 1
    assert "DRYRUN_TABLE" in proc.stderr and "ROOFLINE_TABLE" in proc.stderr
    # and nothing was written
    assert (tmp_path / "EXPERIMENTS.md").read_text().endswith("no markers\n")


def test_check_mode_needs_no_records(tmp_path):
    _write_inputs(tmp_path)
    proc = _run(tmp_path, "--check")
    assert proc.returncode == 0, proc.stderr
    assert "markers present" in proc.stdout
    (tmp_path / "EXPERIMENTS.md").write_text("# empty\n")
    assert _run(tmp_path, "--check").returncode == 1


def test_usage_errors(tmp_path):
    proc = _run(tmp_path)                         # no document at all
    assert proc.returncode == 2
    _write_inputs(tmp_path)
    assert _run(tmp_path).returncode == 2         # markers ok, no records
    assert _run(tmp_path, "missing.jsonl").returncode == 2

"""Inject generated dry-run/roofline tables into EXPERIMENTS.md.

  PYTHONPATH=src python scripts/finalize_experiments.py results/*.jsonl
"""

import re
import sys

sys.path.insert(0, "src")

from repro.launch.report import (dryrun_table, load, roofline_table,  # noqa: E402
                                 summary)


def main() -> None:
    records = load(sys.argv[1:])
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    dry = (summary(records) + "\n\n" + dryrun_table(records))
    roof = (roofline_table(records, "single")
            + "\n\n#### Multi-pod (512 chips)\n\n"
            + roofline_table(records, "multi"))
    text = text.replace("<!-- DRYRUN_TABLE -->", dry)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated:",
          summary(records).splitlines()[0])


if __name__ == "__main__":
    main()

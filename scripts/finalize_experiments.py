#!/usr/bin/env python
"""Inject generated dry-run/roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python scripts/finalize_experiments.py results/*.jsonl
    PYTHONPATH=src python scripts/finalize_experiments.py results/*.jsonl --in-place
    PYTHONPATH=src python scripts/finalize_experiments.py --check

The target document must contain the ``<!-- DRYRUN_TABLE -->`` and
``<!-- ROOFLINE_TABLE -->`` markers; a document missing either fails with
a clear error instead of silently writing nothing. Default mode prints
the finalized document to stdout; ``--in-place`` rewrites the file;
``--check`` only verifies the markers are present (no records needed).

Exit codes: 0 ok, 1 markers missing, 2 usage errors (missing files).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.launch.report import (dryrun_table, load, roofline_table,  # noqa: E402
                                 summary)

MARKERS = ("<!-- DRYRUN_TABLE -->", "<!-- ROOFLINE_TABLE -->")


def missing_markers(text: str) -> list[str]:
    return [m for m in MARKERS if m not in text]


def finalize(text: str, records: list[dict]) -> str:
    dry = summary(records) + "\n\n" + dryrun_table(records)
    roof = (roofline_table(records, "single")
            + "\n\n#### Multi-pod (512 chips)\n\n"
            + roofline_table(records, "multi"))
    return (text.replace("<!-- DRYRUN_TABLE -->", dry)
                .replace("<!-- ROOFLINE_TABLE -->", roof))


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("records", nargs="*", metavar="JSONL",
                    help="dry-run result files (repro.launch dryrun output)")
    ap.add_argument("--file", default="EXPERIMENTS.md", metavar="DOC",
                    help="markdown document carrying the markers "
                         "(default EXPERIMENTS.md)")
    ap.add_argument("--in-place", action="store_true",
                    help="rewrite DOC instead of printing to stdout")
    ap.add_argument("--check", action="store_true",
                    help="only verify DOC contains the markers; writes "
                         "nothing and needs no records")
    args = ap.parse_args()

    doc = pathlib.Path(args.file)
    if not doc.exists():
        print(f"error: no such document: {doc}", file=sys.stderr)
        return 2
    text = doc.read_text(encoding="utf-8")
    absent = missing_markers(text)
    if absent:
        print(f"error: {doc} is missing marker(s): {', '.join(absent)} — "
              "nothing would be injected", file=sys.stderr)
        return 1
    if args.check:
        print(f"{doc}: all {len(MARKERS)} markers present")
        return 0
    if not args.records:
        print("error: no record files given (or use --check)",
              file=sys.stderr)
        return 2
    for rec in args.records:
        if not pathlib.Path(rec).exists():
            print(f"error: no such record file: {rec}", file=sys.stderr)
            return 2
    records = load(args.records)
    finalized = finalize(text, records)
    if args.in_place:
        doc.write_text(finalized, encoding="utf-8")
        print(f"{doc} updated: {summary(records).splitlines()[0]}")
    else:
        sys.stdout.write(finalized)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Execute every fenced shell/python snippet in README.md and docs/.

Documentation examples rot silently; this checker actually runs them.
For each markdown file, every fenced block whose info string is
``python`` or ``bash``/``sh``/``shell`` executes in a scratch directory
seeded with symlinks to the repo's ``src``, ``scripts``, ``benchmarks``,
``examples``, and ``docs`` — so commands are copy-pasteable from the repo
root while artifacts (caches, reports) land in the scratch dir, not the
checkout. Blocks within one file share the scratch dir and run in order,
so a python block may write a cache a later bash block consumes.

Opting a block out (e.g. the full tier-1 run, or full-budget tuning):
put this HTML comment on the line directly above the fence:

    <!-- check-docs: skip -->

Usage:

    python scripts/check_docs.py            # README.md + docs/*.md
    python scripts/check_docs.py docs/cache-format.md

Exit status is non-zero if any snippet fails; wired into tier-1 through
``tests/test_docs.py``.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import re
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent
SKIP_MARK = "<!-- check-docs: skip -->"
# opening fence with arbitrary info string ("```python title=x" included:
# the language is the first word) — a bare "```" closer never reaches this
# regex at top level because block bodies are consumed by the inner loop
FENCE_RE = re.compile(r"^```(.*?)\s*$")
#: repo entries mirrored into each scratch dir (never ``tests``/``pytest.ini``:
#: a doc snippet must not be able to recurse into the test suite by accident)
LINK_ENTRIES = ("src", "scripts", "benchmarks", "examples", "docs")
RUNNABLE = {"python", "bash", "sh", "shell"}
BLOCK_TIMEOUT_S = 240


@dataclasses.dataclass(frozen=True)
class Block:
    lang: str
    code: str
    lineno: int       # 1-based line of the opening fence
    skipped: bool

    @property
    def runnable(self) -> bool:
        return self.lang in RUNNABLE and not self.skipped


def extract_blocks(text: str) -> list[Block]:
    blocks: list[Block] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m is None:
            i += 1
            continue
        info = m.group(1).strip()
        lang = info.split()[0].lower() if info else ""
        skipped = i > 0 and lines[i - 1].strip() == SKIP_MARK
        body: list[str] = []
        j = i + 1
        while j < len(lines) and lines[j].strip() != "```":
            body.append(lines[j])
            j += 1
        blocks.append(Block(lang=lang, code="\n".join(body) + "\n",
                            lineno=i + 1, skipped=skipped))
        i = j + 1
    return blocks


def default_docs(repo: pathlib.Path = REPO) -> list[pathlib.Path]:
    docs = []
    if (repo / "README.md").exists():
        docs.append(repo / "README.md")
    docs.extend(sorted((repo / "docs").glob("*.md")))
    return docs


def _make_scratch(tmp: pathlib.Path) -> None:
    for entry in LINK_ENTRIES:
        target = REPO / entry
        if target.exists():
            (tmp / entry).symlink_to(target)


def _run(block: Block, cwd: pathlib.Path) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + str(REPO) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if block.lang == "python":
        argv = [sys.executable, "-"]
    else:
        argv = ["bash", "-euo", "pipefail", "-s"]
    return subprocess.run(argv, input=block.code, cwd=cwd, env=env,
                          text=True, capture_output=True,
                          timeout=BLOCK_TIMEOUT_S)


def check_file(path: str | os.PathLike,
               blocks: list[Block] | None = None) -> list[str]:
    """Run every runnable block of one markdown file; return failure
    messages (empty == all good). ``blocks`` skips re-parsing when the
    caller already extracted them."""
    path = pathlib.Path(path)
    if blocks is None:
        blocks = extract_blocks(path.read_text(encoding="utf-8"))
    failures: list[str] = []
    with tempfile.TemporaryDirectory(prefix="check-docs-") as tmp:
        scratch = pathlib.Path(tmp)
        _make_scratch(scratch)
        for block in blocks:
            if not block.runnable:
                continue
            try:
                proc = _run(block, scratch)
            except subprocess.TimeoutExpired:
                failures.append(f"{path.name}:{block.lineno} [{block.lang}] "
                                f"timed out after {BLOCK_TIMEOUT_S}s")
                continue
            if proc.returncode != 0:
                tail = (proc.stderr or proc.stdout or "").strip()
                tail = "\n".join(tail.splitlines()[-12:])
                failures.append(f"{path.name}:{block.lineno} [{block.lang}] "
                                f"exited {proc.returncode}\n{tail}")
    return failures


def main(argv: list[str]) -> int:
    files = [pathlib.Path(a) for a in argv] if argv else default_docs()
    any_failed = False
    for f in files:
        blocks = extract_blocks(f.read_text(encoding="utf-8"))
        n_run = sum(1 for b in blocks if b.runnable)
        n_skip = sum(1 for b in blocks if b.lang in RUNNABLE and b.skipped)
        failures = check_file(f, blocks)
        status = "FAIL" if failures else "ok"
        print(f"{f}: {n_run} snippet(s) run, {n_skip} skipped — {status}")
        for msg in failures:
            any_failed = True
            print(f"  {msg}")
    return 1 if any_failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

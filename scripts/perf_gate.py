#!/usr/bin/env python
"""CI performance gate over the run ledger: fail on confirmed regressions.

    PYTHONPATH=src python scripts/perf_gate.py                    # default ledger
    PYTHONPATH=src python scripts/perf_gate.py .tuning_sessions/history.jsonl
    PYTHONPATH=src python scripts/perf_gate.py --dry-run          # never fails CI

For every (benchmark, hardware fingerprint) series in the ledger, the
newest run's incumbent mean is compared against the best historical run
with a Welch CI on the difference of means (reservoir-bootstrap fallback
at low sample counts). A drop is only *confirmed* — and only then does the
gate exit non-zero — when the CI excludes zero AND the effect exceeds
``--min-effect`` (default 2%, the paper's early-termination error budget).
Improvements and statistically-insignificant wobble pass.

Exit codes: 0 clean (or ``--dry-run``), 1 confirmed regression(s),
2 usage errors (missing ledger outside ``--dry-run``).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import Direction  # noqa: E402
from repro.history import RunLedger, detect_regressions  # noqa: E402
from repro.history.regression import MIN_COUNT_WELCH, MIN_EFFECT  # noqa: E402

DEFAULT_LEDGER = ".tuning_sessions/history.jsonl"


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("ledger", nargs="?", default=DEFAULT_LEDGER,
                    help=f"run-ledger JSONL path (default {DEFAULT_LEDGER})")
    ap.add_argument("--benchmark", default=None,
                    help="gate only this benchmark's series")
    ap.add_argument("--fingerprint", default=None,
                    help="gate only this hardware fingerprint's series")
    ap.add_argument("--confidence", type=float, default=0.99)
    ap.add_argument("--min-effect", type=float, default=MIN_EFFECT,
                    metavar="FRAC",
                    help="relative drift below this is never confirmed "
                         f"(default {MIN_EFFECT:g} — the paper's error "
                         "budget)")
    ap.add_argument("--min-count", type=int, default=MIN_COUNT_WELCH,
                    help="pooled samples per run required for the Welch "
                         "path; below it the bootstrap fallback runs")
    ap.add_argument("--direction", default=None,
                    choices=("maximize", "minimize"),
                    help="override the direction stamped on the records")
    ap.add_argument("--dry-run", action="store_true",
                    help="report verdicts but always exit 0 (non-blocking "
                         "CI step; also tolerates a missing ledger)")
    ap.add_argument("--harness", metavar="PATH", default=None,
                    help="also validate the harness self-benchmark "
                         "baseline at PATH (scripts/bench_harness.py "
                         "--check semantics; blocking even with "
                         "--dry-run, because the check is deterministic)")
    args = ap.parse_args()

    if args.harness is not None:
        sys.path.insert(0, str(_REPO / "scripts"))
        from bench_harness import check as harness_check
        rc = harness_check(pathlib.Path(args.harness))
        if rc:
            return rc

    path = pathlib.Path(args.ledger)
    if not path.exists():
        msg = f"perf-gate: no ledger at {path}"
        if args.dry_run:
            print(f"{msg} — nothing to gate (dry-run, ok)")
            return 0
        print(f"error: {msg}", file=sys.stderr)
        return 2

    direction = Direction(args.direction) if args.direction else None
    report = detect_regressions(
        RunLedger(path), benchmark=args.benchmark,
        fingerprint=args.fingerprint, confidence=args.confidence,
        direction=direction, min_effect=args.min_effect,
        min_count=args.min_count)
    sys.stdout.write(report.render_text())
    if args.dry_run:
        if not report.ok:
            print("perf-gate: dry-run — regressions reported but not "
                  "enforced")
        return 0
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

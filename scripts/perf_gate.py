#!/usr/bin/env python
"""CI performance gate over the run ledger: fail on confirmed regressions.

    PYTHONPATH=src python scripts/perf_gate.py                    # default ledger
    PYTHONPATH=src python scripts/perf_gate.py .tuning_sessions/history.jsonl
    PYTHONPATH=src python scripts/perf_gate.py --dry-run          # never fails CI

For every (benchmark, hardware fingerprint) series in the ledger, the
newest run's incumbent mean is compared against the best historical run
with a Welch CI on the difference of means (reservoir-bootstrap fallback
at low sample counts). A drop is only *confirmed* — and only then does the
gate exit non-zero — when the CI excludes zero AND the effect exceeds
``--min-effect`` (default 2%, the paper's early-termination error budget).
Improvements and statistically-insignificant wobble pass.

Under GitHub Actions (``GITHUB_ACTIONS=1``) every confirmed regression
additionally emits a `workflow command
<https://docs.github.com/actions/reference/workflow-commands-for-github-actions>`_
annotation — ``::error`` (``::warning`` in ``--dry-run``) with
``file=<ledger>,line=<N>`` pointing at the candidate run's exact ledger
record, so the verdict surfaces inline on the PR's checks tab.

Exit codes: 0 clean (or ``--dry-run``), 1 confirmed regression(s),
2 usage errors (missing ledger outside ``--dry-run``).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import Direction  # noqa: E402
from repro.history import RunLedger, detect_regressions  # noqa: E402
from repro.history.regression import MIN_COUNT_WELCH, MIN_EFFECT  # noqa: E402

DEFAULT_LEDGER = ".tuning_sessions/history.jsonl"


def _esc_data(s: str) -> str:
    """Workflow-command message escaping (the documented set)."""
    return s.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")


def _esc_prop(s: str) -> str:
    """Workflow-command property escaping: the message set plus the
    property delimiters themselves."""
    return _esc_data(s).replace(":", "%3A").replace(",", "%2C")


def _ledger_line(path: pathlib.Path, benchmark: str, fingerprint: str,
                 run: int):
    """1-based line number of one run record in the ledger file, or None
    (compacted away, or the file changed since the report was built)."""
    try:
        lines = path.read_text(encoding="utf-8").splitlines()
    except OSError:
        return None
    for n, line in enumerate(lines, 1):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if (isinstance(rec, dict) and rec.get("benchmark") == benchmark
                and rec.get("fingerprint") == fingerprint
                and rec.get("run") == run):
            return n
    return None


def emit_annotations(report, ledger_path: pathlib.Path,
                     dry_run: bool = False, out=None) -> int:
    """One GitHub Actions annotation per confirmed regression; returns
    how many were emitted. ``--dry-run`` downgrades them to warnings
    (reported on the PR but never red)."""
    out = sys.stdout if out is None else out
    level = "warning" if dry_run else "error"
    n = 0
    for s in report.series:
        if s.verdict != "regressed" or s.comparison is None:
            continue
        c = s.comparison
        loc = f"file={_esc_prop(str(ledger_path))}"
        line = _ledger_line(ledger_path, s.benchmark, s.fingerprint,
                            c.candidate.run)
        if line is not None:
            loc += f",line={line}"
        title = _esc_prop(f"perf regression: {s.benchmark}")
        msg = _esc_data(
            f"{s.benchmark} @ {s.fingerprint}: run {c.candidate.run} mean "
            f"{c.candidate.mean:.4g} vs best prior {c.baseline.mean:.4g} "
            f"({c.rel_delta:+.2%}, CI [{c.interval.lo:.4g}, "
            f"{c.interval.hi:.4g}])")
        print(f"::{level} {loc},title={title}::{msg}", file=out)
        n += 1
    return n


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("ledger", nargs="?", default=DEFAULT_LEDGER,
                    help=f"run-ledger JSONL path (default {DEFAULT_LEDGER})")
    ap.add_argument("--benchmark", default=None,
                    help="gate only this benchmark's series")
    ap.add_argument("--fingerprint", default=None,
                    help="gate only this hardware fingerprint's series")
    ap.add_argument("--confidence", type=float, default=0.99)
    ap.add_argument("--min-effect", type=float, default=MIN_EFFECT,
                    metavar="FRAC",
                    help="relative drift below this is never confirmed "
                         f"(default {MIN_EFFECT:g} — the paper's error "
                         "budget)")
    ap.add_argument("--min-count", type=int, default=MIN_COUNT_WELCH,
                    help="pooled samples per run required for the Welch "
                         "path; below it the bootstrap fallback runs")
    ap.add_argument("--direction", default=None,
                    choices=("maximize", "minimize"),
                    help="override the direction stamped on the records")
    ap.add_argument("--dry-run", action="store_true",
                    help="report verdicts but always exit 0 (non-blocking "
                         "CI step; also tolerates a missing ledger)")
    ap.add_argument("--harness", metavar="PATH", default=None,
                    help="also validate the harness self-benchmark "
                         "baseline at PATH (scripts/bench_harness.py "
                         "--check semantics; blocking even with "
                         "--dry-run, because the check is deterministic)")
    args = ap.parse_args()

    if args.harness is not None:
        sys.path.insert(0, str(_REPO / "scripts"))
        from bench_harness import check as harness_check
        rc = harness_check(pathlib.Path(args.harness))
        if rc:
            return rc

    path = pathlib.Path(args.ledger)
    if not path.exists():
        msg = f"perf-gate: no ledger at {path}"
        if args.dry_run:
            print(f"{msg} — nothing to gate (dry-run, ok)")
            return 0
        print(f"error: {msg}", file=sys.stderr)
        return 2

    direction = Direction(args.direction) if args.direction else None
    report = detect_regressions(
        RunLedger(path), benchmark=args.benchmark,
        fingerprint=args.fingerprint, confidence=args.confidence,
        direction=direction, min_effect=args.min_effect,
        min_count=args.min_count)
    sys.stdout.write(report.render_text())
    if os.environ.get("GITHUB_ACTIONS", "").lower() in ("1", "true"):
        emit_annotations(report, path, dry_run=args.dry_run)
    if args.dry_run:
        if not report.ok:
            print("perf-gate: dry-run — regressions reported but not "
                  "enforced")
        return 0
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Measurement-soundness linter CLI (see docs/linting.md).

    PYTHONPATH=src python scripts/lint.py                 # all three passes
    PYTHONPATH=src python scripts/lint.py --no-trace      # skip pass 1
    PYTHONPATH=src python scripts/lint.py --json          # machine output
    PYTHONPATH=src python scripts/lint.py src/repro/core  # explicit paths

Passes (stable finding codes — ``repro.lint.CODES``):

  1. workload audit (MS1xx): trace each benchmark registered in
     ``benchmarks.common.AUDITED_WORKLOADS`` and cross-check its declared
     work term against the compiled kernel's cost. Needs jax; skip with
     ``--no-trace`` (CI runs it; a quick pre-commit may not want to).
  2. harness lint (MS2xx): AST timing-pitfall checks over the given
     paths (default: src/ benchmarks/ scripts/).
  3. lock discipline (MS3xx): concurrency invariants of the shared
     JSONL stores (trial cache, run ledger).

Exit codes: 0 = clean (info-level findings allowed), 1 = warning/error
findings, 2 = usage or internal failure. ``--json`` prints the stable
document (``lint_version``, per-finding code/path/line/severity/pass,
summary counts) for CI artifacts.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.lint import (filter_suppressed, findings_to_json,  # noqa: E402
                        check_lock_discipline, lint_paths, worst_severity)

DEFAULT_PATHS = ("src", "benchmarks", "scripts")

#: generated/vendored trees the AST passes skip
EXCLUDE = (".tuning_sessions", "__pycache__", ".git")


def _relativize(findings, root: pathlib.Path):
    out = []
    for f in findings:
        try:
            rel = str(pathlib.Path(f.path).resolve().relative_to(root))
        except ValueError:
            rel = f.path
        out.append(type(f)(code=f.code, path=rel, line=f.line,
                           message=f.message, severity=f.severity,
                           pass_name=f.pass_name))
    return out


def run_workload_audit() -> list:
    """Pass 1 over every registered benchmark (one sample config each)."""
    from benchmarks.common import AUDITED_WORKLOADS
    from repro.lint import audit_benchmark
    findings = []
    for name, (bench, cfg) in sorted(AUDITED_WORKLOADS.items()):
        findings.extend(audit_benchmark(bench, cfg, name=name))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs for the AST passes "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the stable JSON report on stdout")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip pass 1 (workload audit needs jax + traces "
                         "every registered benchmark)")
    args = ap.parse_args(argv)

    root = _REPO
    paths = args.paths or [str(root / p) for p in DEFAULT_PATHS]
    for p in paths:
        if not pathlib.Path(p).exists():
            print(f"lint: no such path: {p}", file=sys.stderr)
            return 2

    findings = []
    try:
        findings += lint_paths(paths, exclude=EXCLUDE)
        findings += check_lock_discipline(root=root)
        if not args.no_trace:
            findings += run_workload_audit()
    except Exception as e:   # internal failure must not read as "clean"
        print(f"lint: internal error: {type(e).__name__}: {e}",
              file=sys.stderr)
        return 2

    findings = _relativize(filter_suppressed(findings), root)
    doc = findings_to_json(findings)
    if args.as_json:
        print(json.dumps(doc, indent=2))
    else:
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
            print(f.render())
        s = doc["summary"]
        print(f"lint: {s['error']} error(s), {s['warning']} warning(s), "
              f"{s['info']} info")
    return 1 if worst_severity(findings) in ("warning", "error") else 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Render roofline dashboards from persisted trial caches — no re-measuring.

    PYTHONPATH=src python scripts/roofline_report.py .tuning_sessions/nightly.jsonl
    PYTHONPATH=src python scripts/roofline_report.py .tuning_sessions \
        --csv roofline.csv
    PYTHONPATH=src python scripts/roofline_report.py .tuning_sessions \
        --html roofline.html --history .tuning_sessions/history.jsonl

Takes one or more cache files (or directories of ``*.jsonl`` session
caches), groups the trials by benchmark × hardware fingerprint, extracts
the DGEMM incumbent (compute ceiling ``F_p``) and the per-size TRIAD
incumbents (memory slopes ``B_a``), and emits a markdown dashboard per
fingerprint — measured peaks with confidence intervals from the stored
Welford moments, an ASCII roofline with achieved-kernel markers, a
%-of-roof gap table — plus a side-by-side comparison across fingerprints.

``--html`` additionally writes a **self-contained HTML dashboard** (inline
CSS/JS/SVG, no external deps); with ``--history LEDGER`` it also embeds
per-series trend lines with CI bands and the regression verdicts from the
performance-history ledger (see ``docs/history.md``); with ``--trace
TRACE`` it embeds a per-trial drill-down table from a session trace
(``scripts/tune.py --trace``, see ``docs/observability.md``).

``--attribute WORKLOAD`` profiles a whole-model workload (train_step /
prefill_step / decode_step / dgemm over a small ModelConfig), joins each
HLO op's cost with its measured device time when the profiler yields
device tracks (static HLO-only attribution otherwise), classifies every
op against the empirical roofs recovered from the given caches, and adds
a per-op attribution section to the markdown and HTML dashboards (see
``docs/attribution.md``). Cache paths become optional in this mode; with
no usable cache the theoretical TPU-v5e roofs stand in (clearly marked).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import build_reports, load_trials  # noqa: E402
from repro.core.report import (DGEMM_BENCHMARK, TRIAD_BENCHMARK,  # noqa: E402
                               render_csv, render_markdown)


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help="cache files or directories of *.jsonl caches "
                         "(optional with --attribute)")
    ap.add_argument("--dgemm-benchmark", default=DGEMM_BENCHMARK,
                    help="benchmark name supplying the compute peak")
    ap.add_argument("--triad-benchmark", default=TRIAD_BENCHMARK,
                    help="benchmark name supplying the bandwidth slopes")
    ap.add_argument("--confidence", type=float, default=0.99)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the markdown dashboard here (default stdout)")
    ap.add_argument("--csv", default=None, metavar="PATH",
                    help="also write the flat CSV (curves, marks, gaps)")
    ap.add_argument("--html", default=None, metavar="PATH",
                    help="also write a self-contained HTML dashboard "
                         "(inline CSS/JS/SVG, no external deps)")
    ap.add_argument("--history", default=None, metavar="LEDGER",
                    help="run-ledger JSONL to embed trend lines and "
                         "regression verdicts into the --html dashboard")
    ap.add_argument("--trace", default=None, metavar="TRACE",
                    help="session trace JSONL (scripts/tune.py --trace) to "
                         "embed a per-trial drill-down table into the "
                         "--html dashboard")
    ap.add_argument("--max-trial-rows", type=int, default=200,
                    metavar="N",
                    help="row cap of the --trace drill-down table "
                         "(default 200)")
    ap.add_argument("--attribute", default=None, metavar="WORKLOAD",
                    help="attribute one workload's HLO ops against the "
                         "empirical roofs (train_step | prefill_step | "
                         "decode_step | dgemm)")
    ap.add_argument("--arch", default=None, metavar="ARCH",
                    help="smoke-scale model architecture for --attribute "
                         "(default: tiny dense toy; see repro.configs)")
    ap.add_argument("--static", action="store_true",
                    help="force static HLO-only attribution (skip the "
                         "profiled invocation)")
    ap.add_argument("--attribution-json", default=None, metavar="PATH",
                    help="write the --attribute report as JSON (CI "
                         "artifact)")
    args = ap.parse_args()

    if not args.paths and not args.attribute:
        ap.error("at least one cache path is required (or --attribute)")

    trials = []
    for p in args.paths:
        path = pathlib.Path(p)
        if not path.exists():
            print(f"error: no such cache: {p}", file=sys.stderr)
            return 2
        trials.extend(load_trials(path))
    if not trials and not args.attribute:
        print("error: no readable trials in the given cache(s)",
              file=sys.stderr)
        return 1

    reports, skipped = build_reports(
        trials, dgemm_benchmark=args.dgemm_benchmark,
        triad_benchmark=args.triad_benchmark, confidence=args.confidence)
    if not reports:
        # a --history ledger can still carry an HTML trend dashboard even
        # when no fingerprint has roofline-complete (dgemm+triad) trials
        print("no reportable fingerprint — need unpruned trials of "
              f"both {args.dgemm_benchmark!r} and {args.triad_benchmark!r}:",
              file=sys.stderr)
        for fp, reason in skipped:
            print(f"  {fp}: {reason}", file=sys.stderr)
        if not (args.html and args.history) and not args.attribute:
            print("error: nothing to render", file=sys.stderr)
            return 1

    attribution = None
    if args.attribute:
        from repro.core.roofline import TPU_V5E  # noqa: E402
        from repro.models.workloads import build_workload  # noqa: E402
        from repro.obs.attribution import Roofs, attribute  # noqa: E402
        from repro.obs.attribution import roofs_from_trials  # noqa: E402

        roofs = roofs_from_trials(args.paths) if args.paths else None
        if roofs is None:
            # no empirical roofs in the caches: classify against the
            # shipped theoretical machine description, clearly marked
            roofs = Roofs(peak_flops=TPU_V5E.peak_flops,
                          bandwidths=dict(TPU_V5E.mem_bandwidths),
                          fingerprint=f"{TPU_V5E.name} (theoretical)")
            print(f"note: no empirical roofs recovered; classifying "
                  f"against {TPU_V5E.name} theoretical peaks",
                  file=sys.stderr)
        try:
            workload = build_workload(args.attribute, args.arch)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        attribution = attribute(workload, roofs, force_static=args.static)
        print(f"attributed {len(attribution.ops)} ops of "
              f"{args.attribute} ({attribution.mode} mode, "
              f"unattributed {attribution.unattributed_frac * 100:.1f}%)",
              file=sys.stderr)
        if args.attribution_json:
            import json

            pathlib.Path(args.attribution_json).write_text(
                json.dumps(attribution.to_json(), indent=2),
                encoding="utf-8")
            print(f"wrote {args.attribution_json}")

    # in the ledger-only continue-path reports is empty: --out/--csv still
    # write (a header-only dashboard/CSV), never silently skip a requested
    # artifact while exiting 0
    markdown = render_markdown(reports, skipped)
    if attribution is not None:
        markdown = markdown + "\n" + attribution.to_markdown()
    if args.out:
        pathlib.Path(args.out).write_text(markdown, encoding="utf-8")
        print(f"wrote {args.out}")
    elif reports or attribution is not None:
        sys.stdout.write(markdown)
    if args.csv:
        pathlib.Path(args.csv).write_text(render_csv(reports),
                                          encoding="utf-8")
        print(f"wrote {args.csv}")
    if args.html:
        import time

        from repro.history import RunLedger, write_dashboard

        ledger = None
        if args.history:
            history_path = pathlib.Path(args.history)
            if not history_path.exists():
                print(f"error: no such ledger: {args.history}",
                      file=sys.stderr)
                return 2
            ledger = RunLedger(history_path)
        trial_rows = ()
        if args.trace:
            trace_path = pathlib.Path(args.trace)
            if not trace_path.exists():
                print(f"error: no such trace: {args.trace}",
                      file=sys.stderr)
                return 2
            from repro.obs import load_events, trial_summaries
            trial_rows = trial_summaries(load_events(trace_path))
        stamp = time.strftime("%Y-%m-%d %H:%M UTC", time.gmtime())
        write_dashboard(args.html, reports, skipped, ledger=ledger,
                        title="Roofline & performance history",
                        subtitle=f"generated {stamp} from "
                                 f"{len(trials)} cached trials",
                        confidence=args.confidence, trials=trial_rows,
                        attribution=attribution,
                        max_trial_rows=args.max_trial_rows)
        print(f"wrote {args.html}")
    return 0


if __name__ == "__main__":
    import os

    try:
        sys.exit(main())
    except BrokenPipeError:
        # stdout closed early (e.g. piped into `head`) — not an error
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)

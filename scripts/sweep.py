#!/usr/bin/env python
"""Run a shape-sweep campaign and query its dispatch-time config oracle.

    PYTHONPATH=src python scripts/sweep.py --session sweep-demo \
        --benchmark synthetic --budget-per-shape 9
    PYTHONPATH=src python scripts/sweep.py --session sweep-demo \
        --benchmark synthetic --predict m=768,n=640
    PYTHONPATH=src python scripts/sweep.py --session sweep-eval \
        --benchmark synthetic --oracle-eval m=512,n=512

A campaign tunes every shape of a grid (``--grid "m=256,512;n=256,512"``,
default: the quick 3×3 GEMM grid) through one resumable session cache;
each shape's surrogate is warmed with the cached trials of its siblings,
so ``--budget-per-shape`` can sit far below the config-space cardinality.
``--predict SHAPE`` then asks the oracle for the best config of an
arbitrary — typically untuned — shape. ``--oracle-eval SHAPE`` is the
holdout protocol: the shape is *excluded* from the campaign, the oracle
predicts its config, and an exhaustive ground-truth pass over that shape
(not cached — ground truth must not leak into the oracle) reports the
prediction's gap to the true optimum and the trial savings. Shapes use
the ``name=value`` key format of ``repro.sweep.shapes`` throughout.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import dataclasses  # noqa: E402

from repro.core import (SearchSpace, TrialCache, Tuner,  # noqa: E402
                        hardware_fingerprint, param)
from repro.core.cache import config_key  # noqa: E402
from repro.sweep import SweepCampaign, parse_shape_key, shape_key  # noqa: E402

from tune import parse_backend  # noqa: E402  (shared CLI backend specs)


def parse_grid(spec: str) -> SearchSpace:
    """'m=256,512,1024;n=256,512' → the shape grid SearchSpace."""
    params = []
    for part in spec.split(";"):
        name, sep, raw = part.partition("=")
        if not sep or not name or not raw:
            raise argparse.ArgumentTypeError(f"malformed grid {spec!r}")
        values = tuple(parse_shape_key(f"v={v}")["v"]
                       for v in raw.split(","))
        params.append(param(name.strip(), values))
    return SearchSpace(params)


def parse_shape(spec: str) -> dict:
    try:
        return parse_shape_key(spec)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--session", required=True,
                    help="campaign name: trials persist under "
                         "<cache-dir>/<session>.jsonl, per-shape "
                         "benchmarks as '<session>@<shape_key>'")
    ap.add_argument("--benchmark", default="synthetic",
                    choices=("synthetic", "dgemm"),
                    help="'synthetic' is the instant shape-conditioned "
                         "objective; 'dgemm' measures the chunked matmul "
                         "family (GFLOP/s)")
    ap.add_argument("--grid", type=parse_grid, default=None,
                    metavar="SPEC",
                    help="shape grid, e.g. 'm=256,512,1024;n=256,512' "
                         "(default: the quick 3×3 GEMM grid)")
    ap.add_argument("--budget-per-shape", type=int, default=None,
                    help="max proposals per shape (default: the sweep "
                         "strategy runs until the config space or the "
                         "evaluation budget is exhausted)")
    ap.add_argument("--predict", type=parse_shape, default=None,
                    metavar="SHAPE",
                    help="after the campaign, ask the oracle for this "
                         "shape's best config, e.g. 'm=768,n=640'")
    ap.add_argument("--oracle-eval", type=parse_shape, default=None,
                    metavar="SHAPE",
                    help="holdout mode: exclude SHAPE from the campaign, "
                         "predict its config, and report the gap to its "
                         "exhaustive optimum plus the trial savings")
    ap.add_argument("--no-tune", action="store_true",
                    help="skip the campaign run; answer --predict/"
                         "--oracle-eval from the existing cache only")
    ap.add_argument("--backend", type=parse_backend, default=None,
                    metavar="SPEC",
                    help="serial | thread[:N] (family closures do not "
                         "pickle into process workers)")
    ap.add_argument("--model", default="ridge", choices=("ridge", "knn"),
                    help="joint shape×config surrogate kind")
    ap.add_argument("--acquisition", default="ei", choices=("ei", "ucb"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="paper Table I budgets and the full shape grid "
                         "instead of quick ones")
    ap.add_argument("--cache-dir", default=".tuning_sessions")
    ap.add_argument("--fresh", action="store_true",
                    help="discard this campaign's cached trials first")
    ap.add_argument("--validate", default="warn",
                    choices=("off", "warn", "strict"))
    ap.add_argument("--trace", nargs="?", const=True, default=False,
                    metavar="PATH",
                    help="record a span trace of the whole campaign "
                         "(default path <cache-dir>/<session>.trace.jsonl; "
                         "see docs/observability.md)")
    args = ap.parse_args()

    from benchmarks.common import (chunked_dgemm_family, gemm_shape_space,
                                   paper_settings, sweep_chunk_space,
                                   sweep_config_space, synthetic_gemm_family)

    quick = not args.full
    shape_space = args.grid or gemm_shape_space(quick)
    if args.benchmark == "synthetic":
        family = synthetic_gemm_family
        config_space = sweep_config_space()
        settings = dataclasses.replace(
            paper_settings(True), max_invocations=2, max_iterations=3,
            use_inner_prune=True)
    else:
        family = chunked_dgemm_family
        config_space = sweep_chunk_space()
        settings = dataclasses.replace(paper_settings(quick),
                                       use_ci_convergence=True,
                                       use_inner_prune=True,
                                       use_outer_prune=True)

    cache_path = pathlib.Path(args.cache_dir) / f"{args.session}.jsonl"
    if args.fresh and cache_path.exists():
        cache_path.unlink()

    # base = the benchmark family, not the session: one session cache can
    # hold synthetic and dgemm sweeps side by side without their per-shape
    # namespaces (and priors/oracle pools) colliding
    campaign = SweepCampaign(
        config_space, shape_space, family, settings, name=args.session,
        base=args.benchmark,
        cache_dir=args.cache_dir, budget_per_shape=args.budget_per_shape,
        model=args.model, acquisition=args.acquisition, seed=args.seed,
        validate=args.validate)

    n_shapes = shape_space.cardinality
    print(f"campaign   : {args.session}  ({cache_path})")
    print(f"fingerprint: {hardware_fingerprint()}")
    print(f"shapes     : {shape_space!r}  ({n_shapes} shapes)")
    print(f"configs    : {config_space!r}  "
          f"({config_space.cardinality} per shape)")
    print(f"cached     : {len(TrialCache(cache_path))} trials")

    holdout = [args.oracle_eval] if args.oracle_eval is not None else []
    if not args.no_tune:
        import time
        result = campaign.run(holdout=holdout, backend=args.backend,
                              timestamp=time.time(), trace=args.trace)
        for o in result.outcomes:
            r = o.result
            print(f"  {shape_key(o.shape):>24s}: best={r.best_config} "
                  f"score={r.best_score:.3f} trials={len(r.trials)} "
                  f"(cached={r.n_cached}, pruned={r.n_pruned})")
        print(f"total      : {result.total_trials} trials across "
              f"{len(result.outcomes)} shapes "
              f"(exhaustive would be "
              f"{n_shapes * config_space.cardinality})")
        if result.trace_path:
            print(f"trace      : {result.trace_path}")

    oracle = campaign.oracle()
    regime = ("warm (joint model)" if oracle.is_warm()
              else "cold (nearest-shape fallback)")
    print(f"oracle     : {regime} — {oracle.n_trials} trials, "
          f"{len(oracle.tuned_shapes)} shapes")

    for label, shape in (("predict", args.predict),
                         ("eval", args.oracle_eval)):
        if shape is None:
            continue
        answer = oracle.best_for(shape)
        print(f"{label:<11s}: {shape_key(shape)} -> {answer.config} "
              f"[{answer.source}"
              + (f", predicted={answer.predicted:.3f}]"
                 if answer.predicted is not None else "]"))
        if label != "eval":
            continue
        # ground truth: exhaustive pass over the held-out shape, not
        # cached — the oracle must never see it
        truth = Tuner(config_space, settings).tune(family(shape),
                                                   validate=args.validate)
        want = config_key(answer.config)
        got = None
        for t in truth.trials:
            if config_key(t.config) == want and not t.result.pruned:
                got = t.result.score
        opt = truth.best_score
        if got is None:
            print("eval       : predicted config was pruned in the "
                  "ground-truth pass — gap unavailable")
            continue
        gap = abs(opt - got) / abs(opt) if opt else 0.0
        spent = campaign.oracle().n_trials
        budget = n_shapes * config_space.cardinality
        print(f"eval       : optimum={truth.best_config} score={opt:.3f}; "
              f"oracle config scored {got:.3f} (gap {100 * gap:.2f}%)")
        print(f"eval       : campaign spent {spent} trials vs {budget} "
              f"exhaustive ({100 * spent / budget:.0f}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Run or resume a named autotuning session from the command line.

    PYTHONPATH=src python scripts/tune.py --session nightly-dgemm
    PYTHONPATH=src python scripts/tune.py --session nightly-dgemm \
        --backend thread:8 --order reverse --full
    PYTHONPATH=src python scripts/tune.py --session adaptive \
        --strategy surrogate --budget 16 --transfer-from nightly-dgemm

Trials persist to ``<cache-dir>/<session>.jsonl`` keyed by (benchmark,
config, hardware fingerprint); re-running the same session skips every
completed config and warm-starts the incumbent from the best cached trial,
so a killed run resumes exactly where it stopped. ``--fresh`` discards the
session's cache first. ``--strategy`` picks the search policy (exhaustive,
halving, random, neighborhood, or the model-guided surrogate/bandit —
see docs/strategies.md), ``--budget`` caps random/neighborhood/surrogate/
bandit proposals, ``--acquisition`` picks the surrogate's scoring rule,
and ``--transfer-from SESSION[:BENCHMARK]`` seeds the search with another
session's cached incumbents (transfer tuning). Halving rung trials are
persisted but never replayed on resume: they are measured under per-rung
budgets, and records only satisfy cache reads made under the same
evaluation settings.

Every completed run also appends its incumbent to the performance-history
ledger (``<cache-dir>/history.jsonl``); ``--history`` prints the series'
trend (sparkline + per-run CIs) and regression verdict afterwards — see
``scripts/perf_gate.py`` and ``docs/history.md``. ``--compact-history N``
compacts that ledger (keep each series' best run plus its N most recent,
drop older superseded runs); it also works standalone, without
``--session``:

    PYTHONPATH=src python scripts/tune.py --compact-history 20
"""

from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import dataclasses  # noqa: E402

from repro.core import (NeighborhoodStrategy, ProcessPoolBackend,  # noqa: E402
                        RandomSearchStrategy, SerialBackend,
                        SimulatedShardedBackend, SuccessiveHalvingStrategy,
                        ThreadPoolBackend, TrialCache, Tuner, TuningSession,
                        hardware_fingerprint)

STRATEGIES = ("exhaustive", "halving", "random", "neighborhood",
              "surrogate", "bandit")


def parse_backend(spec: str):
    """'serial', 'thread:N', 'process:N', or 'simulated:N'."""
    kind, _, arg = spec.partition(":")
    n = int(arg) if arg else 4
    if kind == "serial":
        return SerialBackend()
    if kind == "thread":
        return ThreadPoolBackend(n)
    if kind == "process":
        return ProcessPoolBackend(n)
    if kind == "simulated":
        return SimulatedShardedBackend(n)
    raise argparse.ArgumentTypeError(
        f"unknown backend {spec!r} "
        "(serial | thread[:N] | process[:N] | simulated[:N])")


def make_strategy(args):
    """Build the SearchStrategy the CLI flags describe (None — let the
    Tuner default to the exhaustive strategy honoring --order/--seed)."""
    if args.strategy == "exhaustive":
        return None
    if args.strategy == "halving":
        return SuccessiveHalvingStrategy()
    if args.strategy == "random":
        return RandomSearchStrategy(budget=args.budget, seed=args.seed)
    if args.strategy == "surrogate":
        from repro.surrogate import SurrogateStrategy
        return SurrogateStrategy(budget=args.budget, seed=args.seed,
                                 acquisition=args.acquisition)
    if args.strategy == "bandit":
        from repro.surrogate import BanditStrategy
        return BanditStrategy(budget=args.budget, seed=args.seed)
    return NeighborhoodStrategy(budget=args.budget)


def compact_history(args) -> int:
    """Apply ``RunLedger.compact`` to the cache dir's shared ledger."""
    from repro.history import RunLedger
    path = pathlib.Path(args.cache_dir) / "history.jsonl"
    if not path.exists():
        print(f"compact    : no ledger at {path} — nothing to do")
        return 0
    ledger = RunLedger(path)
    n_before = len(ledger)
    dropped = ledger.compact(keep_last=args.compact_history)
    print(f"compact    : {path} — dropped {dropped} of {n_before} run(s), "
          f"kept {len(ledger)}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--session", default=None,
                    help="session name; trials persist under this name "
                         "(required unless only --compact-history is asked)")
    ap.add_argument("--benchmark", default="dgemm",
                    choices=("dgemm", "triad", "synthetic"),
                    help="'synthetic' is an instant quadratic objective "
                         "for smoke-testing sessions without timing noise")
    ap.add_argument("--backend", type=parse_backend, default=None,
                    metavar="SPEC",
                    help="serial | thread[:N] | process[:N] | simulated[:N]")
    ap.add_argument("--strategy", default="exhaustive", choices=STRATEGIES,
                    help="search strategy (see docs/strategies.md)")
    ap.add_argument("--budget", type=int, default=None,
                    help="max proposals for --strategy random/neighborhood/"
                         "surrogate/bandit")
    ap.add_argument("--acquisition", default="ei", choices=("ei", "ucb"),
                    help="acquisition rule for --strategy surrogate: "
                         "expected improvement against the incumbent's CI "
                         "bound, or UCB at the settings' confidence level")
    ap.add_argument("--transfer-from", default=None, metavar="SESSION[:BENCH]",
                    help="seed the search with another session's cached "
                         "incumbents (default: same benchmark name)")
    ap.add_argument("--order", default="exhaustive",
                    choices=("exhaustive", "reverse", "random"),
                    help="visit order for --strategy exhaustive")
    ap.add_argument("--seed", type=int, default=None,
                    help="shuffle seed for --order/--strategy random")
    ap.add_argument("--full", action="store_true",
                    help="paper Table I budgets instead of quick budgets")
    ap.add_argument("--cache-dir", default=".tuning_sessions")
    ap.add_argument("--no-warm-start", action="store_true",
                    help="do not seed the incumbent from cached trials")
    ap.add_argument("--validate", default="warn",
                    choices=("off", "warn", "strict"),
                    help="pre-run workload audit (repro.lint pass 1): "
                         "cross-check the benchmark's declared work term "
                         "against the traced kernel cost before any trial "
                         "runs; 'strict' aborts on a mismatch")
    ap.add_argument("--fresh", action="store_true",
                    help="discard this session's cached trials first")
    ap.add_argument("--trace", nargs="?", const=True, default=False,
                    metavar="PATH",
                    help="record a span trace of the whole session "
                         "(default path <cache-dir>/<session>.trace.jsonl; "
                         "see docs/observability.md)")
    ap.add_argument("--live", action="store_true",
                    help="print a live one-line campaign status to stderr "
                         "(trials done/pruned/cached, exec-cache hits)")
    ap.add_argument("--report", action="store_true",
                    help="after tuning, render the cache-backed roofline "
                         "dashboard from this session's trial cache")
    ap.add_argument("--history", action="store_true",
                    help="after tuning, print this series' run-ledger "
                         "trend (sparkline + per-run CIs) and its "
                         "regression verdict")
    ap.add_argument("--compact-history", type=int, default=None, metavar="N",
                    help="compact <cache-dir>/history.jsonl: keep each "
                         "series' best run plus its N most recent, drop "
                         "older superseded runs; runs after tuning, or "
                         "standalone when --session is omitted")
    args = ap.parse_args()

    if args.session is None:
        if args.compact_history is None:
            ap.error("--session is required (unless only compacting: "
                     "--compact-history N)")
        return compact_history(args)

    from benchmarks.common import (dgemm_benchmark, dgemm_space,
                                   paper_settings, synthetic_benchmark,
                                   triad_benchmark)

    quick = not args.full
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    if args.benchmark == "dgemm":
        space, benchmark = dgemm_space(quick), dgemm_benchmark
    elif args.benchmark == "synthetic":
        from repro.core import grid
        space = grid(x=tuple(range(12)))
        benchmark = synthetic_benchmark
    else:
        from repro.core import grid
        sizes = (2 ** 16, 2 ** 20, 2 ** 24) if quick else \
            tuple(2 ** e for e in range(14, 28, 2))
        space = grid(n_bytes=sizes)
        benchmark = triad_benchmark
        # Each TRIAD size probes a different memory subsystem: the sizes
        # are measurements, not competitors. Pruning a slow DRAM stream
        # against the cache-resident incumbent would cache a truncated
        # bandwidth estimate and drop that subsystem from --report.
        settings = dataclasses.replace(settings, use_inner_prune=False,
                                       use_outer_prune=False)

    cache_path = pathlib.Path(args.cache_dir) / f"{args.session}.jsonl"
    if args.fresh and cache_path.exists():
        cache_path.unlink()

    strategy = make_strategy(args)
    if strategy is None:
        tuner = Tuner(space, settings, order=args.order, seed=args.seed)
    else:
        tuner = Tuner(space, settings, strategy=strategy)
    session = TuningSession(args.session, tuner, benchmark,
                            cache_dir=args.cache_dir,
                            warm_start=not args.no_warm_start,
                            benchmark_name=args.benchmark,
                            trace=args.trace)

    seeds = []
    if args.transfer_from is not None:
        source, _, source_bench = args.transfer_from.partition(":")
        source_bench = source_bench or args.benchmark
        source_path = pathlib.Path(args.cache_dir) / f"{source}.jsonl"
        if source_path.exists():
            donor = TrialCache(source_path)
            seeds = donor.suggest_seeds(source_bench,
                                        direction=settings.direction)
        print(f"transfer   : {len(seeds)} seed(s) from session "
              f"{source!r} (benchmark {source_bench!r})")

    print(f"session    : {args.session}  ({cache_path})")
    print(f"fingerprint: {hardware_fingerprint()}")
    print(f"strategy   : {args.strategy}"
          + (f" (order={args.order})" if args.strategy == "exhaustive" else "")
          + (f" (acquisition={args.acquisition})"
             if args.strategy == "surrogate" else "")
          + (f" (budget={args.budget})" if args.budget is not None else ""))
    print(f"space      : {space!r}  ({space.cardinality} configs)")
    print(f"cached     : {len(session.cache)} trials "
          f"({session.cache.n_stale} stale skipped)")

    done = 0
    if args.live:
        from repro.core import default_cache
        from repro.obs.metrics import metrics as obs_metrics
        live_base = obs_metrics().snapshot()
        exec_base = default_cache().stats

    def live_status():
        c = obs_metrics().delta(live_base).get("counters", {})
        x = default_cache().stats.delta(exec_base)
        line = (f"trials {c.get('trials.completed', 0)} "
                f"(pruned {c.get('trials.pruned', 0)}, "
                f"cached {c.get('trials.cached', 0)}) | "
                f"exec-cache hits {x.hits} compiles {x.compiles}")
        print(f"\r[live] {line}   ", end="", file=sys.stderr, flush=True)

    def progress(cfg, res):
        nonlocal done
        done += 1
        tag = "PRUNED" if res.pruned else f"{res.score:10.2f}"
        print(f"  [{done:4d}/{space.cardinality}] {cfg} -> {tag} "
              f"({res.stop_reason})")
        if args.live:
            live_status()

    import time

    result = session.run(backend=args.backend, progress=progress,
                         seeds=seeds, timestamp=time.time(),
                         validate=args.validate)
    if args.live:
        print(file=sys.stderr)   # terminate the \r status line
    print(f"\nbest      : {result.best_config}  score={result.best_score}")
    print(f"trials    : {len(result.trials)}  cached={result.n_cached}  "
          f"pruned={result.n_pruned}  samples={result.total_samples}")
    print(f"strategy  : {result.strategy}  rounds={len(result.batches)}  "
          f"seeded={result.n_seeded}")
    print(f"backend   : {result.backend}  workers={result.n_workers}  "
          f"wall={result.parallel_time_s:.2f}s "
          f"(serial-equivalent {result.serial_time_s:.2f}s)")
    if result.improvements:
        trail = " -> ".join(f"{score:.2f}"
                            for _, score in result.improvements)
        print(f"incumbent : {trail}")
    if result.trace_path:
        print(f"trace     : {result.trace_path}")

    if args.history:
        from repro.history import detect_regressions, render_trend_text
        runs = session.ledger.series(args.benchmark,
                                     session.cache.fingerprint)
        print()
        print(render_trend_text(runs))
        report = detect_regressions(session.ledger,
                                    benchmark=args.benchmark,
                                    fingerprint=session.cache.fingerprint)
        print(report.render_text(), end="")

    if args.report:
        from repro.core import build_reports, load_trials
        from repro.core.report import render_markdown
        reports, skipped = build_reports(load_trials(cache_path))
        if reports:
            print()
            print(render_markdown(reports, skipped))
        else:
            print("\n[report] nothing to render: the cache needs unpruned "
                  "'dgemm' and 'triad' trials (run both benchmarks under "
                  "this session name).")
            for fp, reason in skipped:
                print(f"[report]   {fp}: {reason}")

    if args.compact_history is not None:
        compact_history(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Run or resume a named autotuning session from the command line.

    PYTHONPATH=src python scripts/tune.py --session nightly-dgemm
    PYTHONPATH=src python scripts/tune.py --session nightly-dgemm \
        --backend thread:8 --order reverse --full

Trials persist to ``<cache-dir>/<session>.jsonl`` keyed by (benchmark,
config, hardware fingerprint); re-running the same session skips every
completed config and warm-starts the incumbent from the best cached trial,
so a killed run resumes exactly where it stopped. ``--fresh`` discards the
session's cache first.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import dataclasses  # noqa: E402

from repro.core import (SerialBackend, SimulatedShardedBackend,  # noqa: E402
                        ThreadPoolBackend, Tuner, TuningSession,
                        hardware_fingerprint)


def parse_backend(spec: str):
    """'serial', 'thread:N', or 'simulated:N'."""
    kind, _, arg = spec.partition(":")
    n = int(arg) if arg else 4
    if kind == "serial":
        return SerialBackend()
    if kind == "thread":
        return ThreadPoolBackend(n)
    if kind == "simulated":
        return SimulatedShardedBackend(n)
    raise argparse.ArgumentTypeError(
        f"unknown backend {spec!r} (serial | thread[:N] | simulated[:N])")


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--session", required=True,
                    help="session name; trials persist under this name")
    ap.add_argument("--benchmark", default="dgemm",
                    choices=("dgemm", "triad", "synthetic"),
                    help="'synthetic' is an instant quadratic objective "
                         "for smoke-testing sessions without timing noise")
    ap.add_argument("--backend", type=parse_backend, default=None,
                    metavar="SPEC", help="serial | thread[:N] | simulated[:N]")
    ap.add_argument("--order", default="exhaustive",
                    choices=("exhaustive", "reverse", "random"))
    ap.add_argument("--seed", type=int, default=None,
                    help="shuffle seed for --order random")
    ap.add_argument("--full", action="store_true",
                    help="paper Table I budgets instead of quick budgets")
    ap.add_argument("--cache-dir", default=".tuning_sessions")
    ap.add_argument("--no-warm-start", action="store_true",
                    help="do not seed the incumbent from cached trials")
    ap.add_argument("--fresh", action="store_true",
                    help="discard this session's cached trials first")
    ap.add_argument("--report", action="store_true",
                    help="after tuning, render the cache-backed roofline "
                         "dashboard from this session's trial cache")
    args = ap.parse_args()

    from benchmarks.common import (dgemm_benchmark, dgemm_space,
                                   paper_settings, triad_invocation_factory)

    quick = not args.full
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    if args.benchmark == "dgemm":
        space, benchmark = dgemm_space(quick), dgemm_benchmark
    elif args.benchmark == "synthetic":
        from repro.core import grid
        space = grid(x=tuple(range(12)))
        benchmark = lambda cfg: (  # noqa: E731
            lambda: (lambda: 100.0 - (cfg["x"] - 7) ** 2))
    else:
        from repro.core import grid
        sizes = (2 ** 16, 2 ** 20, 2 ** 24) if quick else \
            tuple(2 ** e for e in range(14, 28, 2))
        space = grid(n_bytes=sizes)
        benchmark = lambda cfg: triad_invocation_factory(cfg["n_bytes"])  # noqa: E731
        # Each TRIAD size probes a different memory subsystem: the sizes
        # are measurements, not competitors. Pruning a slow DRAM stream
        # against the cache-resident incumbent would cache a truncated
        # bandwidth estimate and drop that subsystem from --report.
        settings = dataclasses.replace(settings, use_inner_prune=False,
                                       use_outer_prune=False)

    cache_path = pathlib.Path(args.cache_dir) / f"{args.session}.jsonl"
    if args.fresh and cache_path.exists():
        cache_path.unlink()

    tuner = Tuner(space, settings, order=args.order, seed=args.seed)
    session = TuningSession(args.session, tuner, benchmark,
                            cache_dir=args.cache_dir,
                            warm_start=not args.no_warm_start,
                            benchmark_name=args.benchmark)
    print(f"session    : {args.session}  ({cache_path})")
    print(f"fingerprint: {hardware_fingerprint()}")
    print(f"space      : {space!r}  ({space.cardinality} configs)")
    print(f"cached     : {len(session.cache)} trials "
          f"({session.cache.n_stale} stale skipped)")

    done = 0

    def progress(cfg, res):
        nonlocal done
        done += 1
        tag = "PRUNED" if res.pruned else f"{res.score:10.2f}"
        print(f"  [{done:4d}/{space.cardinality}] {cfg} -> {tag} "
              f"({res.stop_reason})")

    result = session.run(backend=args.backend, progress=progress)
    print(f"\nbest      : {result.best_config}  score={result.best_score}")
    print(f"trials    : {len(result.trials)}  cached={result.n_cached}  "
          f"pruned={result.n_pruned}  samples={result.total_samples}")
    print(f"backend   : {result.backend}  workers={result.n_workers}  "
          f"wall={result.parallel_time_s:.2f}s "
          f"(serial-equivalent {result.serial_time_s:.2f}s)")
    if result.improvements:
        trail = " -> ".join(f"{score:.2f}"
                            for _, score in result.improvements)
        print(f"incumbent : {trail}")

    if args.report:
        from repro.core import build_reports, load_trials
        from repro.core.report import render_markdown
        reports, skipped = build_reports(load_trials(cache_path))
        if reports:
            print()
            print(render_markdown(reports, skipped))
        else:
            print("\n[report] nothing to render: the cache needs unpruned "
                  "'dgemm' and 'triad' trials (run both benchmarks under "
                  "this session name).")
            for fp, reason in skipped:
                print(f"[report]   {fp}: {reason}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

#!/usr/bin/env python
"""Harness self-benchmark: how fast is the measurement loop itself?

    PYTHONPATH=src python scripts/bench_harness.py            # measure + write
    PYTHONPATH=src python scripts/bench_harness.py --check    # validate baseline

The paper's search-time wins come from cutting sample counts; this
script watches the next term — *per-trial harness overhead*. A tuning
campaign evaluates each configuration for the first time, so trials are
compile-cold by nature: every session here runs over configs whose
shapes this process has never compiled, once through each harness
generation:

  legacy  the pre-PR idiom: ``jax.jit`` re-entered inside every
          invocation factory, operand data regenerated through eager
          ``jax.random`` every invocation, one blocking sync per timed
          sample (``timed_sampler``)
  fast    the shipping path: AOT ``ExecutableCache`` for kernels,
          pipelined compiles overlapping the previous trial's
          measurement, batched ``steady_sampler`` observations,
          host-side seeded data generation reused per config

and reports the **non-measured wall time per trial**::

    non_measured = session_wall - measured_s
    measured_s   = dispatch + sync phase-bucket seconds

where the *measured* seconds are exactly the samplers' own timed
windows, recorded by :class:`repro.core.PhaseProfiler` from inside
``timed_sampler``/``steady_sampler``. Everything else the session spent
— setup, tracing, compiling, data generation, pre-heats, bookkeeping —
is non-measured overhead. Both terms come from the same session, so the
accounting needs no external per-kernel reference time and no
cross-session subtraction (which would amplify run-to-run noise).

Each repetition draws a fresh set of cold shapes; legacy and fast get
interleaved, disjoint shape sets of the same size class so neither can
hit compilation caches warmed by the other. The per-mode result is the
median across repetitions.

The acceptance targets (ISSUE 8) are embedded in the JSON and enforced
by ``--check`` (schema + thresholds of the committed baseline — no
measurement, deterministic) and by the measuring run itself:

  * non-measured wall per trial: fast >= 3x lower than legacy on both
    the synthetic (tiny-kernel) and DGEMM families
  * batched ``steady_sampler`` agrees with unbatched ``timed_sampler``
    within 2% (the paper's error budget) on a DGEMM workload large
    enough that per-call sync wake-up (~0.1 ms on this host) is inside
    the budget for the unbatched sampler too
"""

from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import statistics
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_REPO), str(_REPO / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

BENCH_VERSION = 2
DEFAULT_JSON = "BENCH_harness.json"
MIN_SPEEDUP = 3.0        # ISSUE 8 acceptance: >=3x lower non-measured time
MAX_REL_DIFF = 0.02      # paper's 2% error budget for sampler agreement


# ---------------------------------------------------------------------------
# Measurement (imports jax lazily so --check stays dependency-free)
# ---------------------------------------------------------------------------

# Families: (name, fixed dims, k-generator params, steady batch).
# k varies per trial so every config is a genuinely cold shape; the two
# modes take interleaved k values from the same arithmetic progression,
# so their compile and kernel cost distributions match.
_FAMILIES = [
    # tiny kernel: measurement is ~15us/call, so the harness itself
    # dominates — the family that stresses overhead hardest
    ("synthetic", {"n": 64, "m": 64}, {"base": 32, "step": 4}, 64),
    # the paper's DGEMM at host scale: real measurement load per trial
    ("dgemm", {"n": 512, "m": 512}, {"base": 160, "step": 16}, 8),
]
_CONFIGS_PER_SESSION = 4


def _session_spaces(dims, kgen, rep):
    """Disjoint, interleaved cold-shape grids for (legacy, fast) at one
    repetition: 8 fresh k values, evens to legacy, odds to fast."""
    from repro.core import grid
    lo = rep * 2 * _CONFIGS_PER_SESSION
    ks = [kgen["base"] + kgen["step"] * (lo + j)
          for j in range(2 * _CONFIGS_PER_SESSION)]
    legacy = grid(n=(dims["n"],), m=(dims["m"],), k=tuple(ks[0::2]))
    fast = grid(n=(dims["n"],), m=(dims["m"],), k=tuple(ks[1::2]))
    return legacy, fast


def _legacy_benchmark(work_of):
    """The pre-PR invocation factory, verbatim idiom: fresh trace + fresh
    eagerly generated data every invocation, one sync per sample."""
    import jax
    import jax.numpy as jnp

    from repro.core import timed_sampler

    def benchmark(cfg):
        n, m, k = cfg["n"], cfg["m"], cfg["k"]
        flops = work_of(cfg)
        invocation = itertools.count()

        def factory():
            seed = (n * 1_000_003 + m * 10_007 + k * 101
                    + next(invocation)) % (2 ** 31)
            key = jax.random.key(seed)
            a = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
            b = jax.random.normal(jax.random.fold_in(key, 2), (k, m))
            f = jax.jit(jnp.dot)   # lint: ok=MS207 — the legacy baseline under test
            jax.block_until_ready(f(a, b))      # pre-heat
            def run():
                jax.block_until_ready(f(a, b))
            return timed_sampler(run, work=flops / 1e9)

        return factory

    return benchmark


def _fast_benchmark(batch):
    """The shipping cached/batched factory (benchmarks.common)."""
    from benchmarks.common import dgemm_invocation_factory, dgemm_precompile

    def benchmark(cfg):
        return dgemm_invocation_factory(
            cfg["n"], cfg["m"], cfg["k"],
            sampler="steady", batch=batch, reuse_data=True)

    benchmark.precompile = dgemm_precompile
    return benchmark


def _session(benchmark, space, settings):
    """One profiled tuning session. The record is self-contained: wall
    and phase buckets come from the same run, and
    ``non_measured = wall - (dispatch + sync)`` subtracts exactly the
    samplers' own timed windows."""
    from repro.core import PhaseProfiler, Tuner

    prof = PhaseProfiler()
    with prof:
        result = Tuner(space, settings).tune(benchmark, validate="off")
    buckets = prof.to_json()
    measured = sum(buckets.get(p, {}).get("seconds", 0.0)
                   for p in ("dispatch", "sync"))
    wall = result.total_time_s
    trials = len(result.trials)
    return {
        "wall_s": round(wall, 6),
        "measured_s": round(measured, 6),
        "non_measured_s": round(max(wall - measured, 0.0), 6),
        "non_measured_per_trial_s": round(
            max(wall - measured, 0.0) / trials, 6),
        "trials": trials,
        "samples": result.total_samples,
        "n_precompiled": result.n_precompiled,
        "phases": buckets,
    }


def _run_family(name, dims, kgen, batch, settings, reps, work_of):
    runs = {"legacy": [], "fast": []}
    for rep in range(reps):
        legacy_space, fast_space = _session_spaces(dims, kgen, rep)
        order = [("legacy", _legacy_benchmark(work_of), legacy_space),
                 ("fast", _fast_benchmark(batch), fast_space)]
        if rep % 2:     # alternate order so drift cannot favour one mode
            order.reverse()
        for mode, benchmark, space in order:
            runs[mode].append(_session(benchmark, space, settings))

    def summarize(rs):
        med = statistics.median(r["non_measured_per_trial_s"] for r in rs)
        pick = min(rs, key=lambda r: abs(r["non_measured_per_trial_s"] - med))
        out = dict(pick)
        out["non_measured_per_trial_s"] = med   # median across repetitions
        out["reps"] = [r["non_measured_per_trial_s"] for r in rs]
        return out

    leg, fst = summarize(runs["legacy"]), summarize(runs["fast"])
    fst["batch"] = batch
    speedup = (leg["non_measured_per_trial_s"]
               / max(fst["non_measured_per_trial_s"], 1e-9))
    return {
        "configs_per_session": _CONFIGS_PER_SESSION,
        "sessions_per_mode": reps,
        "batch": batch,
        "legacy": leg,
        "fast": fst,
        "speedup_non_measured": round(speedup, 2),
    }


def _sampler_agreement(obs: int = 8, batch: int = 4) -> dict:
    """Batched vs unbatched score on a 2048^3 DGEMM: both samplers
    measure the same cached executable on the same data, observations
    interleaved in alternating order so frequency drift hits both
    streams alike. The kernel must be large enough for two reasons: the
    per-call sync wake-up the unbatched sampler necessarily includes
    (~0.1 ms on this host) must sit inside the 2% budget — on small
    kernels that wake-up *is* the divergence steady_sampler exists to
    remove — and single-observation frequency jitter (+-10% at ~15 ms
    on this host) must average out within one call (~140 ms here)."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import _dgemm_data, dgemm_flops
    from repro.core import default_cache, steady_sampler, timed_sampler

    n = 2048
    a, b = _dgemm_data(n, n, n, seed=7, dtype=jnp.float32)
    f = default_cache().compile(jnp.dot, (a, b))
    jax.block_until_ready(f(a, b))      # warm
    work = dgemm_flops(n, n, n) / 1e9
    timed = timed_sampler(lambda: jax.block_until_ready(f(a, b)), work=work)
    steady = steady_sampler(lambda: f(a, b), work=work,
                            sync=jax.block_until_ready, batch=batch)
    timed(), steady()                   # one warm round each
    t_scores, s_scores = [], []
    for i in range(obs):
        if i % 2:
            s_scores.append(steady())
            t_scores.append(timed())
        else:
            t_scores.append(timed())
            s_scores.append(steady())
    t_med = statistics.median(t_scores)
    s_med = statistics.median(s_scores)
    rel = abs(s_med - t_med) / t_med
    return {"workload": f"dgemm[{n}x{n}x{n}]", "batch": batch,
            "observations": obs,
            "timed_gflops": round(t_med, 3),
            "steady_gflops": round(s_med, 3),
            "rel_diff": round(rel, 5)}


def measure(reps: int = 3) -> dict:
    import jax
    import jax.numpy as jnp

    from benchmarks.common import dgemm_flops
    from repro.core import Direction, EvaluationSettings

    # global first-use warmup on sacrificial shapes: pays jax's one-time
    # tracing/compilation machinery, attributed to neither mode
    key = jax.random.key(0)
    jax.block_until_ready(jax.random.normal(key, (48, 48)))
    jax.block_until_ready(jax.jit(jnp.dot)(jnp.ones((48, 40)),
                                           jnp.ones((40, 48))))

    def work_of(cfg):
        return dgemm_flops(cfg["n"], cfg["m"], cfg["k"])

    # fixed-count settings: both modes run the same trial structure
    settings = EvaluationSettings(max_invocations=3, max_iterations=8,
                                  max_time_s=60.0,
                                  direction=Direction.MAXIMIZE)
    families = {}
    for name, dims, kgen, batch in _FAMILIES:
        families[name] = _run_family(name, dims, kgen, batch,
                                     settings, reps, work_of)

    agreement = _sampler_agreement()
    ok = (all(f["speedup_non_measured"] >= MIN_SPEEDUP
              for f in families.values())
          and agreement["rel_diff"] <= MAX_REL_DIFF)
    return {
        "bench_version": BENCH_VERSION,
        "generated_by": "scripts/bench_harness.py",
        "protocol": ("cold-shape sessions (every trial compiles fresh, "
                     "the tuning-campaign regime); non_measured = wall - "
                     "(dispatch + sync phase buckets), i.e. wall minus "
                     "the samplers' own timed windows; median over "
                     "repetitions on disjoint interleaved shape sets"),
        "settings": {"max_invocations": settings.max_invocations,
                     "max_iterations": settings.max_iterations},
        "families": families,
        "agreement": agreement,
        "checks": {"min_speedup": MIN_SPEEDUP,
                   "max_rel_diff": MAX_REL_DIFF, "pass": ok},
    }


# ---------------------------------------------------------------------------
# Reporting / gating
# ---------------------------------------------------------------------------

def render(doc: dict) -> str:
    lines = ["harness self-benchmark:"]
    for name, fam in doc["families"].items():
        leg = fam["legacy"]["non_measured_per_trial_s"] * 1e3
        fst = fam["fast"]["non_measured_per_trial_s"] * 1e3
        lines.append(
            f"  {name:<10s} non-measured/trial: legacy {leg:8.3f} ms  "
            f"fast {fst:8.3f} ms  ({fam['speedup_non_measured']:.1f}x, "
            f"B={fam['batch']})")
    agr = doc["agreement"]
    lines.append(
        f"  agreement  timed {agr['timed_gflops']:.1f} vs steady "
        f"{agr['steady_gflops']:.1f} GFLOP/s on {agr['workload']} "
        f"(rel diff {agr['rel_diff'] * 100:.2f}%)")
    checks = doc["checks"]
    lines.append(
        f"  targets    >={checks['min_speedup']:g}x speedup, "
        f"<={checks['max_rel_diff'] * 100:g}% sampler divergence: "
        f"{'PASS' if checks['pass'] else 'FAIL'}")
    return "\n".join(lines)


def check(path: pathlib.Path) -> int:
    """Validate the committed baseline: schema + recorded thresholds.

    Deterministic (no measurement, no jax import) so it can block in
    ci.sh; the GitHub workflow re-measures fresh, non-blocking.
    """
    if not path.exists():
        print(f"error: no harness baseline at {path}", file=sys.stderr)
        return 2
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except json.JSONDecodeError as e:
        print(f"error: {path} is not valid JSON: {e}", file=sys.stderr)
        return 2
    problems = []
    if doc.get("bench_version") != BENCH_VERSION:
        problems.append(f"bench_version != {BENCH_VERSION}")
    fams = doc.get("families", {})
    for required in ("synthetic", "dgemm"):
        if required not in fams:
            problems.append(f"missing family {required!r}")
    for name, fam in fams.items():
        spd = fam.get("speedup_non_measured", 0.0)
        if spd < MIN_SPEEDUP:
            problems.append(
                f"{name}: speedup {spd} < required {MIN_SPEEDUP}")
        for mode in ("legacy", "fast"):
            if "non_measured_per_trial_s" not in fam.get(mode, {}):
                problems.append(f"{name}.{mode}: missing accounting")
    rel = doc.get("agreement", {}).get("rel_diff")
    if rel is None or rel > MAX_REL_DIFF:
        problems.append(f"sampler agreement rel_diff {rel} > {MAX_REL_DIFF}")
    if not doc.get("checks", {}).get("pass"):
        problems.append("checks.pass is not true")
    if problems:
        print(f"harness baseline {path}: FAIL")
        for p in problems:
            print(f"  - {p}")
        return 1
    spds = ", ".join(f"{n} {fam['speedup_non_measured']}x"
                     for n, fam in fams.items())
    print(f"harness baseline {path}: ok ({spds}; "
          f"agreement {rel * 100:.2f}%)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--json", default=DEFAULT_JSON, metavar="PATH",
                    help=f"output path (default {DEFAULT_JSON})")
    ap.add_argument("--check", action="store_true",
                    help="validate an existing JSON instead of measuring")
    ap.add_argument("--reps", type=int, default=3,
                    help="cold-shape sessions per mode (median taken)")
    args = ap.parse_args()

    path = pathlib.Path(args.json)
    if args.check:
        return check(path)       # deterministic: no jax, no obs imports

    # the measuring run is itself traced: every legacy/fast session's
    # trial/invocation/phase spans land in one JSONL + Perfetto artifact
    # next to the JSON (uploaded by CI) — the harness eating its own
    # observability dog food
    from repro.obs import TraceRecorder, write_chrome_trace
    trace_path = path.with_name(path.stem + ".trace.jsonl")
    trace_path.unlink(missing_ok=True)   # append-only file: one run per artifact
    with TraceRecorder(trace_path, session="bench-harness") as rec:
        doc = measure(reps=args.reps)
    perfetto = write_chrome_trace(
        path.with_name(path.stem + ".perfetto.json"), rec.events())
    path.write_text(json.dumps(doc, indent=2, sort_keys=False) + "\n",
                    encoding="utf-8")
    print(render(doc))
    print(f"wrote {path}")
    print(f"wrote {trace_path} and {perfetto}")
    return 0 if doc["checks"]["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

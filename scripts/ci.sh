#!/usr/bin/env bash
# One-command CI gate: byte-compile everything, run the tier-1 suite,
# then execute every fenced doc snippet.
#
#     bash scripts/ci.sh            # ~5 min on the reference container
#
# compileall runs first (seconds, catches syntax errors before the slow
# pytest pass); check_docs.py runs last and also executes inside tier-1
# via tests/test_docs.py, so a standalone failure here without a pytest
# failure means the docs changed after the suite was last green.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== compileall =="
python -m compileall -q src scripts benchmarks examples tests

echo "== measurement-soundness lint =="
# blocking: exit 1 on any warning/error finding (see docs/linting.md)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/lint.py

echo "== ruff (style baseline, when available) =="
# the reference container does not ship ruff; GitHub CI installs a pinned
# one (see .github/workflows/ci.yml) so the style gate still blocks there
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "ruff not installed; skipping style baseline"
fi

echo "== tier-1 pytest =="
# coverage rides along when pytest-cov is installed (the reference
# container has none; the CI coverage job pins it) — same single pytest
# pass either way, and the threshold below only reports, never blocks
if python -c "import pytest_cov" >/dev/null 2>&1; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        --cov=repro --cov-report=xml --cov-report=term
    python - <<'EOF'
import xml.etree.ElementTree as ET
rate = float(ET.parse("coverage.xml").getroot().get("line-rate"))
target = 0.80
mark = "meets" if rate >= target else "is below"
print(f"line coverage {rate:.1%} {mark} the {target:.0%} target "
      "(non-blocking)")
EOF
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
fi

echo "== doc snippets =="
python scripts/check_docs.py

echo "== harness self-benchmark baseline =="
# blocking but deterministic: validates the committed BENCH_harness.json
# (schema + recorded speedup/agreement thresholds) without measuring.
# The GitHub `bench-harness` job re-measures fresh, non-blocking.
python scripts/bench_harness.py --check

echo "== perf gate (dry-run, non-blocking) =="
# reports ledger drift without failing the build; flip off --dry-run in a
# deployment with a persistent .tuning_sessions/history.jsonl to enforce.
# The ledger path is explicit so a cold runner (no .tuning_sessions/)
# prints "nothing to gate" deterministically regardless of cwd defaults.
python scripts/perf_gate.py --dry-run .tuning_sessions/history.jsonl

echo "== traced smoke session =="
# end-to-end observability gate: one tiny synthetic session with tracing
# on must produce a non-empty trace whose trial spans cover every trial
# and export to a clean Perfetto document (see docs/observability.md)
SMOKE_DIR=$(mktemp -d)
trap 'rm -rf "$SMOKE_DIR"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/tune.py \
    --session ci-smoke --benchmark synthetic --backend thread:4 \
    --cache-dir "$SMOKE_DIR" --trace > /dev/null
SMOKE_DIR="$SMOKE_DIR" PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python - <<'EOF'
import os
from repro.obs import load_events, to_chrome_trace, validate_chrome_trace
path = os.path.join(os.environ["SMOKE_DIR"], "ci-smoke.trace.jsonl")
events = load_events(path)
if not events:
    raise SystemExit(f"empty or unparseable trace at {path}")
trials = [e for e in events
          if e.get("type") == "span" and e.get("cat") == "trial"]
if len(trials) != 12:
    raise SystemExit(f"expected 12 trial spans, got {len(trials)}")
problems = validate_chrome_trace(to_chrome_trace(events))
if problems:
    raise SystemExit("Perfetto export invalid: " + "; ".join(problems))
print(f"trace ok: {len(events)} events, {len(trials)} trial spans")
EOF

echo "== tiny-model attribution smoke =="
# blocking: the static (HLO-only) attribution path must label every op
# of a tiny train step with a subsystem and a %-of-roof, and report the
# remainder as exactly zero (see docs/attribution.md)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
from repro.core.roofline import TPU_V5E
from repro.models.workloads import build_workload
from repro.obs.attribution import Roofs, attribute

roofs = Roofs(peak_flops=TPU_V5E.peak_flops,
              bandwidths=dict(TPU_V5E.mem_bandwidths),
              fingerprint=f"{TPU_V5E.name} (theoretical)")
report = attribute(build_workload("train_step"), roofs, force_static=True)
if not report.ops:
    raise SystemExit("attribution produced no ops")
bad = [op.name for op in report.ops
       if not op.subsystem or op.pct_of_roof is None]
if bad:
    raise SystemExit(f"unlabeled ops in static attribution: {bad[:5]}")
if report.unattributed_s != 0.0:
    raise SystemExit(
        f"static remainder must be 0, got {report.unattributed_s}")
print(f"attribution ok: {len(report.ops)} ops labeled, "
      f"{report.total_flops:.3g} FLOPs, remainder 0")
EOF

echo "== ci.sh: all green =="
